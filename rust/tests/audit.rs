//! Integration coverage for `nn::audit` — the compile-time dataflow /
//! aliasing verifier, the kernel-dispatch classifier, and the static
//! cost model, driven over real compiled networks through the crate's
//! public API.
//!
//! The acceptance checks of the subsystem live here: every shipped
//! architecture (including the JSON-loaded `examples/archs/*.json`
//! paper variants) audits clean across all three layers; the
//! general-conv fallback in `mixed.json` is flagged off the vectorized
//! fast path; every seeded dataflow-defect class — broken shape chain,
//! aliased delta planes, missing/mis-sized arenas, duplicate PRNG
//! streams — is detected; and the registry-coverage guard fails loudly
//! when a newly registered layer kind is not answering dispatch/cost.

use chaos_phi::config::{Act, ArchSpec, LayerSpec};
use chaos_phi::nn::audit::{
    expected_extents, shape_rows, verify_arena_layout, verify_shape_rows, ShapeRow, AUDIT_CAP,
};
use chaos_phi::nn::{
    audit_cost, audit_dataflow, audit_dispatch, layer, ArenaExtent, ArenaLayout, DataflowDefect,
    Dispatch, KernelPath, Network, OpCost,
};
use chaos_phi::perfmodel::derived_ops;
use chaos_phi::util::Json;

/// Every kind the audits below exercise; the coverage guard asserts this
/// set matches the registry, so a newly registered built-in kind fails
/// loudly until it is covered here too.
const COVERED_KINDS: &[&str] = &["input", "conv", "pool", "avgpool", "fc", "dropout", "output"];

/// An architecture touching every built-in kind, including the general
/// (padded + strided) conv path and both activations.
fn zoo_arch() -> ArchSpec {
    ArchSpec {
        name: "audit-zoo".into(),
        layers: vec![
            LayerSpec::Input { side: 13 },
            LayerSpec::conv_ex(4, 4, 1, 1, Act::Relu), // padded: 12x12
            LayerSpec::MaxPool { kernel: 2 },          // 6x6
            LayerSpec::conv_ex(6, 2, 2, 0, Act::ScaledTanh), // strided: 3x3
            LayerSpec::AvgPool { kernel: 3 },          // 1x1
            LayerSpec::Dropout { rate: 0.4 },
            LayerSpec::fc_act(17, Act::Relu),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    }
}

// ---------------------------------------------------------------------
// Positive: shipped architectures audit clean across all three layers
// ---------------------------------------------------------------------

#[test]
fn shipped_architectures_audit_clean() {
    for name in ["small", "medium", "large", "tiny"] {
        let net = Network::from_name(name).unwrap();
        let flow = audit_dataflow(&net);
        assert!(flow.is_clean(), "{name}: {}", flow.to_text());
        assert_eq!(flow.arch, name);
        assert_eq!(flow.layers, net.ops.len());
        assert_eq!(flow.cap, AUDIT_CAP);

        // Each report's JSON view carries its schema tag and round-trips.
        let j = Json::parse(&flow.to_json().pretty()).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("chaos.analyze.dataflow/v1"));
        assert_eq!(j.get("clean").and_then(Json::as_bool), Some(true));

        let kernels = audit_dispatch(&net);
        let kj = Json::parse(&kernels.to_json().pretty()).unwrap();
        assert_eq!(kj.get("schema").and_then(Json::as_str), Some("chaos.analyze.kernel/v2"));
        // /v2 publishes the GEMM tile constants for the cost model.
        let tiles = kj.get("tiles").expect("kernel/v2 carries a tiles object");
        assert!(tiles.get("gemm_kc").is_some() && tiles.get("gemm_mr").is_some());
        assert_eq!(kernels.rows.len(), net.ops.len());

        let cost = audit_cost(&net, 32);
        let cj = Json::parse(&cost.to_json().pretty()).unwrap();
        assert_eq!(cj.get("schema").and_then(Json::as_str), Some("chaos.analyze.cost/v1"));
        assert_eq!(cj.get("layers").and_then(Json::as_arr).map(|a| a.len()), Some(net.ops.len()));
        assert!(cost.total_fwd_flops() > 0.0, "{name}");
        assert!(
            cost.total_bwd_flops() > cost.total_fwd_flops(),
            "{name}: backward must cost strictly more than forward"
        );
    }
}

#[test]
fn example_arch_files_audit_clean() {
    // The CI loop runs `chaos analyze --cost` over the same files; this
    // pins the library-level contract behind that loop.
    for path in ["examples/archs/small.json", "examples/archs/mixed.json"] {
        let arch = ArchSpec::from_file(path).unwrap_or_else(|e| panic!("{path}: {e:#}"));
        let net = Network::new(arch);
        let flow = audit_dataflow(&net);
        assert!(flow.is_clean(), "{path}: {}", flow.to_text());
    }
}

// ---------------------------------------------------------------------
// Dispatch classification: the mixed arch's general conv is flagged
// ---------------------------------------------------------------------

#[test]
fn mixed_arch_general_conv_routes_through_im2col_gemm() {
    // mixed.json's first conv is stride-2/pad-2: since the batch-lane
    // rework it compiles to the im2col+GEMM staging route and is *on*
    // the fast path — `general-fallback` no longer appears for any
    // built-in op. Its second conv is stride-1/pad-0 and stays on the
    // vectorized weight-stationary kernels.
    let net = Network::new(ArchSpec::from_file("examples/archs/mixed.json").unwrap());
    let report = audit_dispatch(&net);

    let convs: Vec<_> = report.rows.iter().filter(|r| r.kind == "conv").collect();
    assert_eq!(convs.len(), 2);
    assert_eq!(convs[0].dispatch.forward, KernelPath::Im2colGemm);
    assert_eq!(convs[0].dispatch.backward, KernelPath::Im2colGemm);
    assert!(convs[0].dispatch.fast());
    assert_eq!(convs[1].dispatch.forward, KernelPath::VectorizedPlain);
    assert!(convs[1].dispatch.fast());

    assert!(
        report.off_fast_path().is_empty(),
        "mixed.json should audit fully fast: {}",
        report.to_text()
    );

    // The JSON view reports the same class.
    let j = Json::parse(&report.to_json().pretty()).unwrap();
    let rows = j.get("layers").and_then(Json::as_arr).unwrap();
    let row = &rows[convs[0].layer];
    assert_eq!(row.get("forward").and_then(Json::as_str), Some("im2col-gemm"));
    assert_eq!(row.get("fast").and_then(Json::as_bool), Some(true));
}

#[test]
fn paper_archs_are_fully_vectorized() {
    // The paper nets use stride-1/pad-0 convs throughout; with the
    // batch-lane pool/dropout kernels and the blocked fc GEMM every
    // built-in op now classifies fast.
    for name in ["small", "medium", "large"] {
        let net = Network::from_name(name).unwrap();
        for r in &audit_dispatch(&net).rows {
            match r.kind.as_str() {
                "conv" => assert_eq!(r.dispatch.forward, KernelPath::VectorizedPlain, "{name}"),
                "fc" | "output" => {
                    assert_eq!(r.dispatch.forward, KernelPath::BlockedGemm, "{name}")
                }
                "pool" | "avgpool" => {
                    assert_eq!(r.dispatch.forward, KernelPath::BatchLane, "{name}")
                }
                "dropout" => {
                    assert_eq!(r.dispatch.forward, KernelPath::BlockElementwise, "{name}")
                }
                "input" => assert_eq!(r.dispatch.forward, KernelPath::Inert, "{name}"),
                other => panic!("{name}: unexpected kind {other}"),
            }
            if r.kind != "input" {
                assert!(r.dispatch.fast(), "{name}: {} off the fast path", r.kind);
            }
        }
    }
}

#[test]
fn no_builtin_op_is_off_the_fast_path() {
    // Regression guard for the batch-lane rework: `off_fast_path()` is
    // empty — no `per-sample-loop`, no `general-fallback` — for every
    // shipped architecture and every example arch file, zoo included.
    for net in [
        Network::from_name("small").unwrap(),
        Network::from_name("medium").unwrap(),
        Network::from_name("large").unwrap(),
        Network::from_name("tiny").unwrap(),
        Network::new(zoo_arch()),
        Network::new(ArchSpec::from_file("examples/archs/small.json").unwrap()),
        Network::new(ArchSpec::from_file("examples/archs/mixed.json").unwrap()),
    ] {
        let report = audit_dispatch(&net);
        assert!(
            report.off_fast_path().is_empty(),
            "{}: built-in ops left on the SIMD work-list: {}",
            net.arch.name,
            report.to_text()
        );
        for r in &report.rows {
            assert_ne!(r.dispatch.forward, KernelPath::PerSampleLoop, "{}", net.arch.name);
            assert_ne!(r.dispatch.forward, KernelPath::GeneralFallback, "{}", net.arch.name);
            assert_ne!(r.dispatch.backward, KernelPath::PerSampleLoop, "{}", net.arch.name);
            assert_ne!(r.dispatch.backward, KernelPath::GeneralFallback, "{}", net.arch.name);
        }
    }
}

// ---------------------------------------------------------------------
// Negative: every seeded dataflow-defect class is detected
// ---------------------------------------------------------------------

fn chain_of(net: &Network) -> Vec<ShapeRow> {
    let rows = shape_rows(net);
    assert!(verify_shape_rows(&rows).is_empty(), "baseline chain must be clean");
    rows
}

#[test]
fn broken_shape_chain_is_detected() {
    let net = Network::new(ArchSpec::tiny());

    // Break the chain: layer 2 claims to consume 5 more elements than
    // layer 1 produces (both sides consistently, so only the chain trips).
    let mut rows = chain_of(&net);
    rows[2].op_in += 5;
    rows[2].dims_in += 5;
    let defects = verify_shape_rows(&rows);
    assert!(
        defects.iter().any(|d| matches!(d, DataflowDefect::BrokenChain { layer: 2, .. })),
        "{defects:?}"
    );

    // An op disagreeing with the compiled dims table is its own class.
    let mut rows = chain_of(&net);
    rows[1].op_out += 1;
    let defects = verify_shape_rows(&rows);
    assert!(
        defects.iter().any(|d| matches!(
            d,
            DataflowDefect::OpShapeMismatch { layer: 1, side: "out", .. }
        )),
        "{defects:?}"
    );
}

#[test]
fn aliased_and_missized_arenas_are_detected() {
    // Start from the real layout of a real scratch, then seed defects.
    let net = Network::new(ArchSpec::tiny());
    let plan = net.batch_plan(AUDIT_CAP).unwrap();
    let mut scratch = plan.scratch_seeded(0);
    let expected = expected_extents(&net, AUDIT_CAP);

    // The forward-only scratch is *missing* the backward arenas: the
    // verifier reports them (delta planes sized 0 vs. their real planes).
    let defects = verify_arena_layout(&scratch.layout(), &expected);
    assert!(
        defects.iter().any(|d| matches!(d, DataflowDefect::ArenaMisSized { .. })),
        "forward-only scratch must fail the backward-arena check: {defects:?}"
    );

    // Fully materialized, it verifies clean…
    let full = audit_dataflow(&net);
    assert!(full.is_clean(), "{}", full.to_text());

    // …and seeding each defect class into that clean layout trips it.
    scratch.ensure_backward_arenas(&net);
    let clean = scratch.layout();
    assert!(verify_arena_layout(&clean, &expected).is_empty());

    // Aliased ping-pong delta planes: point delta_b into delta_a.
    let mut aliased = clean.clone();
    let a = aliased.extents.iter().find(|e| e.name == "delta_a").unwrap().addr;
    let b = aliased.extents.iter_mut().find(|e| e.name == "delta_b").unwrap();
    b.addr = a + 4; // overlaps all but delta_a's first element
    let classes: Vec<_> =
        verify_arena_layout(&aliased, &expected).iter().map(|d| d.class()).collect();
    assert!(classes.contains(&"arena-overlap"), "{classes:?}");

    // A whole arena gone missing.
    let mut gone = clean.clone();
    gone.extents.retain(|e| e.name != "grad_buf");
    let classes: Vec<_> = verify_arena_layout(&gone, &expected).iter().map(|d| d.class()).collect();
    assert_eq!(classes, vec!["arena-missing"]);

    // Duplicate per-layer PRNG streams: dropout masks would repeat
    // across layers (same class the per-worker reseed guards against).
    let mut dup = clean.clone();
    assert!(dup.rng_streams.len() >= 2);
    dup.rng_streams[1] = dup.rng_streams[0];
    let defects = verify_arena_layout(&dup, &expected);
    assert!(
        defects.iter().any(|d| matches!(d, DataflowDefect::DuplicateRngStream { .. })),
        "{defects:?}"
    );
}

#[test]
fn hand_built_degenerate_layouts_are_rejected() {
    // Pure-data path: no Network at all, mirroring how a defective
    // runtime-registered kind would present to the verifier.
    let expected = vec![("acts[0]".to_string(), 8), ("delta_a".to_string(), 16)];
    let layout = ArenaLayout {
        cap: 2,
        extents: vec![
            ArenaExtent { name: "acts[0]".into(), addr: 0, len: 4 }, // half the plane
            ArenaExtent { name: "delta_a".into(), addr: 8, len: 16 }, // starts inside acts[0]
        ],
        rng_streams: vec![1, 2, 1],
    };
    let classes: Vec<_> = verify_arena_layout(&layout, &expected).iter().map(|d| d.class()).collect();
    assert!(classes.contains(&"arena-size"), "{classes:?}");
    assert!(classes.contains(&"arena-overlap"), "{classes:?}");
    assert!(classes.contains(&"dup-rng-stream"), "{classes:?}");
}

// ---------------------------------------------------------------------
// Registry coverage: every registered kind answers dispatch/cost
// ---------------------------------------------------------------------

#[test]
fn every_registered_kind_answers_dispatch_and_cost() {
    let mut covered: Vec<String> = COVERED_KINDS.iter().map(|s| s.to_string()).collect();
    covered.sort();
    assert_eq!(
        layer::names(),
        covered,
        "a registered kind is missing from the audit coverage zoo"
    );

    let net = Network::new(zoo_arch());
    for kind in COVERED_KINDS.iter().filter(|k| **k != "input") {
        assert!(
            net.ops.iter().any(|op| op.kind() == *kind),
            "zoo arch does not instantiate kind '{kind}'"
        );
    }

    // Every op classifies its dispatch and prices its cost: finite,
    // non-negative, and strictly positive FLOPs for every driven layer.
    let cost = audit_cost(&net, AUDIT_CAP);
    for r in &cost.rows {
        let c = &r.cost;
        for v in [c.fwd_flops, c.bwd_flops, c.param_bytes, c.fwd_act_bytes, c.bwd_act_bytes] {
            assert!(v.is_finite() && v >= 0.0, "layer {} ({}): bad cost {v}", r.layer, r.kind);
        }
        if r.kind == "input" {
            assert_eq!(r.dispatch, Dispatch::inert());
            assert_eq!(c.fwd_flops, 0.0);
        } else {
            assert!(c.fwd_flops > 0.0, "layer {} ({}): zero forward flops", r.layer, r.kind);
            assert!(c.bwd_flops > 0.0, "layer {} ({}): zero backward flops", r.layer, r.kind);
            assert_ne!(r.dispatch.forward, KernelPath::Inert, "{}", r.kind);
        }
    }

    // Parameterized kinds charge their spans; parameterless kinds don't.
    for r in &cost.rows {
        match r.kind.as_str() {
            "conv" | "fc" | "output" => assert!(r.cost.param_bytes > 0.0, "{}", r.kind),
            _ => assert_eq!(r.cost.param_bytes, 0.0, "{}", r.kind),
        }
    }
}

#[test]
fn conservative_default_is_slow_but_priced() {
    // The trait defaults a runtime-registered kind inherits: off the fast
    // path (so the classifier surfaces it) yet still costed, with the
    // parameter span charged once per batch.
    let d = Dispatch::per_sample();
    assert_eq!(d.forward, KernelPath::PerSampleLoop);
    assert!(!d.fast(), "an un-overridden kind must land on the work-list");

    let c = OpCost::generic(100, 50, 10);
    assert_eq!(c.fwd_flops, 150.0);
    assert_eq!(c.bwd_flops, 300.0);
    assert_eq!(c.param_bytes, 40.0);
    assert!(c.fwd_intensity(32) > c.fwd_intensity(1), "batching amortizes the span");
}

// ---------------------------------------------------------------------
// Cross-check: perfmodel's derived constants are the audit totals
// ---------------------------------------------------------------------

#[test]
fn perfmodel_derived_ops_equal_audit_totals() {
    for arch in [ArchSpec::small(), ArchSpec::medium(), zoo_arch()] {
        let net = Network::new(arch);
        let (fwd, bwd) = derived_ops(&net);
        let cost = audit_cost(&net, 1);
        assert_eq!(fwd, cost.total_fwd_flops(), "{}", net.arch.name);
        assert_eq!(bwd, cost.total_bwd_flops(), "{}", net.arch.name);
    }
}
