//! Acceptance coverage for the open layer API.
//!
//! 1. **Bit-parity**: a frozen reimplementation of the enum-era
//!    orchestrator (the closed `match`-on-`LayerSpec` dispatch that
//!    `Network::forward`/`backward` shipped before the `LayerOp` pipeline),
//!    built on the same public kernels, must produce *bit-identical*
//!    probabilities, per-layer gradients and SGD trajectories on every
//!    paper architecture at threads=1.
//! 2. **Openness**: a layer kind registered at runtime from this (external)
//!    test crate trains end-to-end through `chaos::Trainer` under every
//!    registered update policy — no crate-internal changes.

use chaos_phi::chaos::{policy, Trainer};
use chaos_phi::config::{Act, ArchSpec, LayerSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::activation::{apply_scaled_tanh, scaled_tanh_deriv_from_y, softmax};
use chaos_phi::nn::conv::{conv_backward, conv_forward, ConvShape};
use chaos_phi::nn::fc::{fc_backward, fc_forward, FcShape};
use chaos_phi::nn::layer::{self, LayerCtx, LayerKind};
use chaos_phi::nn::pool::{pool_backward, pool_forward, PoolShape};
use chaos_phi::nn::{Acts, LayerDims, LayerOp, Network, OpScratch, Shape};
use chaos_phi::util::Pcg32;
use std::ops::Range;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// The frozen enum-era reference implementation (paper layer kinds only).
// ---------------------------------------------------------------------------

struct Legacy<'a> {
    dims: &'a [LayerDims],
    acts: Vec<Vec<f32>>,
    switches: Vec<Vec<u32>>,
    delta_a: Vec<f32>,
    delta_b: Vec<f32>,
}

fn conv_shape(d: &LayerDims, maps: usize, kernel: usize) -> ConvShape {
    ConvShape {
        in_maps: d.in_maps,
        in_side: d.in_side,
        out_maps: maps,
        out_side: d.out_side,
        kernel,
    }
}

impl<'a> Legacy<'a> {
    fn new(dims: &'a [LayerDims]) -> Legacy<'a> {
        let max_act = dims.iter().map(|d| d.out_len()).max().unwrap();
        Legacy {
            dims,
            acts: dims.iter().map(|d| vec![0.0; d.out_len()]).collect(),
            switches: dims
                .iter()
                .map(|d| match d.spec {
                    LayerSpec::MaxPool { .. } => vec![0u32; d.out_len()],
                    _ => Vec::new(),
                })
                .collect(),
            delta_a: vec![0.0; max_act],
            delta_b: vec![0.0; max_act],
        }
    }

    /// The pre-refactor forward: one `match` per layer.
    fn forward(&mut self, params: &[f32], image: &[f32]) -> &[f32] {
        self.acts[0].copy_from_slice(image);
        for l in 1..self.dims.len() {
            let d = &self.dims[l];
            let (prev, rest) = self.acts.split_at_mut(l);
            let input = &prev[l - 1];
            let out = &mut rest[0];
            match d.spec {
                LayerSpec::Conv { maps, kernel, stride, pad, act } => {
                    assert_eq!((stride, pad, act), (1, 0, Act::ScaledTanh), "paper conv only");
                    let p = &params[d.params.clone()];
                    let (w, b) = p.split_at(d.weights);
                    conv_forward(&conv_shape(d, maps, kernel), input, w, b, out);
                    apply_scaled_tanh(out);
                }
                LayerSpec::MaxPool { kernel } => {
                    let shape = PoolShape {
                        maps: d.in_maps,
                        in_side: d.in_side,
                        out_side: d.out_side,
                        kernel,
                    };
                    pool_forward(&shape, input, out, &mut self.switches[l]);
                }
                LayerSpec::FullyConnected { neurons, act } => {
                    assert_eq!(act, Act::ScaledTanh, "paper fc only");
                    let shape = FcShape { inputs: d.in_maps, outputs: neurons };
                    let p = &params[d.params.clone()];
                    let (w, b) = p.split_at(d.weights);
                    fc_forward(&shape, input, w, b, out);
                    apply_scaled_tanh(out);
                }
                LayerSpec::Output { classes } => {
                    let shape = FcShape { inputs: d.in_maps, outputs: classes };
                    let p = &params[d.params.clone()];
                    let (w, b) = p.split_at(d.weights);
                    fc_forward(&shape, input, w, b, out);
                    softmax(out);
                }
                ref other => panic!("legacy reference cannot run {other:?}"),
            }
        }
        self.acts.last().unwrap()
    }

    /// The pre-refactor backward: delta seeded with p − onehot, one `match`
    /// per layer walking back, the *previous* layer's tanh derivative
    /// applied after each step, grads emitted per parameterized layer.
    fn backward(&mut self, params: &mut [f32], label: usize, eta: Option<f32>) -> Vec<f32> {
        let n = self.dims.len();
        let mut all_grads = vec![0.0f32; self.dims.last().unwrap().params.end];
        {
            let probs = self.acts.last().unwrap();
            let delta = &mut self.delta_a[..probs.len()];
            delta.copy_from_slice(probs);
            delta[label] -= 1.0;
        }
        for l in (1..n).rev() {
            let d = self.dims[l].clone();
            let is_first = l == 1;
            let input_len = d.in_len();
            match d.spec {
                LayerSpec::Conv { maps, kernel, .. } => {
                    let p: Vec<f32> = params[d.params.clone()].to_vec();
                    let (w, _b) = p.split_at(d.weights);
                    let gbuf = &mut all_grads[d.params.clone()];
                    let (wg, bg) = gbuf.split_at_mut(d.weights);
                    let delta = &self.delta_a[..d.out_len()];
                    let dinput: &mut [f32] =
                        if is_first { &mut [] } else { &mut self.delta_b[..input_len] };
                    conv_backward(
                        &conv_shape(&d, maps, kernel),
                        &self.acts[l - 1],
                        w,
                        delta,
                        wg,
                        bg,
                        dinput,
                    );
                    if let Some(eta) = eta {
                        // The sequential engine's instant local update.
                        for (w, g) in params[d.params.clone()].iter_mut().zip(gbuf.iter()) {
                            *w -= eta * g;
                        }
                    }
                }
                LayerSpec::MaxPool { kernel } => {
                    let shape = PoolShape {
                        maps: d.in_maps,
                        in_side: d.in_side,
                        out_side: d.out_side,
                        kernel,
                    };
                    let delta = &self.delta_a[..d.out_len()];
                    pool_backward(&shape, delta, &self.switches[l], &mut self.delta_b[..input_len]);
                }
                LayerSpec::FullyConnected { neurons: outs, .. }
                | LayerSpec::Output { classes: outs } => {
                    let shape = FcShape { inputs: d.in_maps, outputs: outs };
                    let p: Vec<f32> = params[d.params.clone()].to_vec();
                    let (w, _b) = p.split_at(d.weights);
                    let gbuf = &mut all_grads[d.params.clone()];
                    let (wg, bg) = gbuf.split_at_mut(d.weights);
                    let delta = &self.delta_a[..d.out_len()];
                    let dinput: &mut [f32] =
                        if is_first { &mut [] } else { &mut self.delta_b[..input_len] };
                    fc_backward(&shape, &self.acts[l - 1], w, delta, wg, bg, dinput);
                    if let Some(eta) = eta {
                        for (w, g) in params[d.params.clone()].iter_mut().zip(gbuf.iter()) {
                            *w -= eta * g;
                        }
                    }
                }
                ref other => panic!("legacy reference cannot run {other:?}"),
            }
            if !is_first {
                let prev_has_tanh = matches!(
                    self.dims[l - 1].spec,
                    LayerSpec::Conv { .. } | LayerSpec::FullyConnected { .. }
                );
                if prev_has_tanh {
                    let prev_acts = &self.acts[l - 1];
                    let din = &mut self.delta_b[..input_len];
                    for (dv, &y) in din.iter_mut().zip(prev_acts.iter()) {
                        *dv *= scaled_tanh_deriv_from_y(y);
                    }
                }
                std::mem::swap(&mut self.delta_a, &mut self.delta_b);
            }
        }
        all_grads
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn compiled_pipeline_is_bit_identical_to_enum_dispatch_on_paper_archs() {
    for name in ["tiny", "small", "medium", "large"] {
        let net = Network::from_name(name).unwrap();
        let mut params = net.init_params(5);
        let mut legacy_params = params.clone();
        let mut scratch = net.scratch();
        let mut legacy = Legacy::new(&net.dims);
        let mut rng = Pcg32::seeded(31);
        let side = net.arch.input_side();
        let steps = if name == "large" { 2 } else { 3 };

        for step in 0..steps {
            let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let label = rng.range(0, 10);

            // Forward parity (no updates).
            let probs = net.forward(&params.as_slice(), &img, &mut scratch, None).to_vec();
            let legacy_probs = legacy.forward(&legacy_params, &img).to_vec();
            assert_eq!(bits(&probs), bits(&legacy_probs), "{name} step {step}: forward probs");

            // Gradient parity (no updates).
            let mut grads = vec![0.0f32; net.total_params];
            net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, g| {
                grads[d.params.clone()].copy_from_slice(g);
            });
            let legacy_grads = legacy.backward(&mut legacy_params, label, None);
            assert_eq!(bits(&grads), bits(&legacy_grads), "{name} step {step}: gradients");

            // SGD trajectory parity (instant per-layer updates, the
            // sequential engine's path).
            let eta = 0.01;
            net.sgd_step(&mut params, &img, label, eta, &mut scratch, None);
            legacy.forward(&legacy_params, &img);
            legacy.backward(&mut legacy_params, label, Some(eta));
            assert_eq!(
                bits(&params),
                bits(&legacy_params),
                "{name} step {step}: parameters diverged after sgd_step"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Openness: a runtime-registered kind trains under every policy.
// ---------------------------------------------------------------------------

/// Elementwise abs layer: y = |x| (derivative from y is sign-of-input,
/// recoverable from the stored input).
struct AbsKind;

#[derive(Debug)]
struct AbsOp {
    shape: Shape,
}

impl LayerKind for AbsKind {
    fn name(&self) -> &'static str {
        "abs"
    }

    fn from_json(&self, _body: &chaos_phi::util::Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::custom("abs", vec![]))
    }

    fn to_json(&self, _spec: &LayerSpec) -> chaos_phi::util::Json {
        chaos_phi::util::Json::obj(vec![])
    }

    fn out_shape(
        &self,
        _spec: &LayerSpec,
        input: Shape,
        _ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        Ok(input)
    }

    fn compile(&self, _spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        Ok(Box::new(AbsOp {
            shape: Shape { maps: dims.out_maps, side: dims.out_side, flat: dims.flat },
        }))
    }
}

impl LayerOp for AbsOp {
    fn kind(&self) -> &'static str {
        "abs"
    }

    fn in_shape(&self) -> Shape {
        self.shape
    }

    fn out_shape(&self) -> Shape {
        self.shape
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        for (o, &x) in out.iter_mut().zip(input) {
            *o = x.abs();
        }
    }

    fn backward(
        &self,
        _: &[f32],
        acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return;
        }
        for ((di, &d), &x) in delta_in.iter_mut().zip(delta_out.iter()).zip(acts.input) {
            *di = if x < 0.0 { -d } else { d };
        }
    }
}

#[test]
fn runtime_registered_kind_trains_under_every_policy() {
    // Ignore the duplicate error when the test binary runs this twice.
    let _ = layer::register(Arc::new(AbsKind));
    assert!(layer::names().iter().any(|n| n == "abs"));
    assert!(layer::register(Arc::new(AbsKind)).is_err(), "duplicates rejected");

    let arch = ArchSpec {
        name: "absnet".into(),
        layers: vec![
            LayerSpec::Input { side: 13 },
            LayerSpec::conv(3, 4), // 10x10
            LayerSpec::MaxPool { kernel: 2 },
            LayerSpec::custom("abs", vec![]),
            LayerSpec::fc(8),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    };
    // Serializes and reloads like a built-in.
    let round = ArchSpec::from_json(&arch.to_json()).unwrap();
    assert_eq!(arch, round);

    let train_set = generate_synthetic(120, 1, &SynthConfig::default()).resize(13);
    let test_set = generate_synthetic(40, 2, &SynthConfig::default()).resize(13);
    for name in policy::names() {
        let r = Trainer::new()
            .arch(arch.clone())
            .config(TrainConfig {
                epochs: 2,
                threads: 3,
                eta0: 0.05,
                eta_decay: 0.95,
                seed: 42,
                validation_fraction: 0.25,
                eval_batch: 32,
                ..TrainConfig::default()
            })
            .policy_name(&name)
            .unwrap()
            .run(&train_set, &test_set)
            .unwrap();
        assert_eq!(r.epochs[0].train.images, 120, "{name}: trained every image");
        let first = &r.epochs[0];
        let last = r.epochs.last().unwrap();
        assert!(last.train.loss.is_finite() && last.train.loss > 0.0, "{name}");
        assert!(
            last.train.loss < first.train.loss * 1.5,
            "{name}: training is not exploding ({} -> {})",
            first.train.loss,
            last.train.loss
        );
    }
}
