//! Property-based invariants across modules, driven by the in-crate
//! proptest harness (util::proptest).

use chaos_phi::chaos::{Sampler, SharedParams};
use chaos_phi::config::{ArchSpec, LayerSpec};
use chaos_phi::nn::{compute_dims, Network};
use chaos_phi::perfmodel::{PerfModel, Scenario};
use chaos_phi::phisim::{simulate, SimConfig};
use chaos_phi::util::proptest::{check_close, run, Config};
use chaos_phi::util::Pcg32;

/// Random valid architecture generator: input side, conv/pool pairs, fc.
fn random_arch(rng: &mut Pcg32, size: usize) -> ArchSpec {
    let mut layers = vec![];
    let mut side = 8 + rng.range(0, 8 + size);
    layers.push(LayerSpec::Input { side });
    let n_conv = 1 + rng.range(0, 2);
    for _ in 0..n_conv {
        let max_k = side.saturating_sub(2).clamp(1, 4);
        let kernel = 1 + rng.range(0, max_k);
        if kernel > side {
            break;
        }
        layers.push(LayerSpec::conv(1 + rng.range(0, 4), kernel));
        side = side - kernel + 1;
        // Pool with a non-trivial divisor kernel (identity P1 pools are
        // rejected by the validator outside the paper's "large" net).
        let divisors: Vec<usize> = (2..=side.min(3)).filter(|d| side % d == 0).collect();
        if !divisors.is_empty() {
            let k = divisors[rng.range(0, divisors.len())];
            layers.push(LayerSpec::MaxPool { kernel: k });
            side /= k;
        }
        if side < 3 {
            break;
        }
    }
    layers.push(LayerSpec::fc(1 + rng.range(0, 12)));
    layers.push(LayerSpec::Output { classes: 10 });
    ArchSpec { name: "prop".into(), layers, paper_epochs: 1 }
}

#[test]
fn gradcheck_on_random_architectures() {
    run(
        Config { cases: 10, max_size: 6, seed: 0xFACE },
        |rng, size| {
            let arch = random_arch(rng, size);
            let seed = rng.next_u64();
            (arch, seed)
        },
        |(arch, seed)| {
            if arch.validate().is_err() {
                return Ok(()); // generator produced a degenerate stack; skip
            }
            let net = Network::new(arch.clone());
            let mut params = net.init_params(*seed);
            let mut scratch = net.scratch();
            let mut rng = Pcg32::seeded(*seed ^ 0x1234);
            let side = arch.input_side();
            let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let label = rng.range(0, 10);

            net.forward(&params.as_slice(), &img, &mut scratch, None);
            let mut analytic = vec![0.0f32; net.total_params];
            net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, g| {
                analytic[d.params.clone()].copy_from_slice(g);
            });

            // Check a handful of random parameters by central differences.
            let h = 1e-3f32;
            for _ in 0..8 {
                let idx = rng.range(0, net.total_params);
                let orig = params[idx];
                params[idx] = orig + h;
                net.forward(&params.as_slice(), &img, &mut scratch, None);
                let lp = net.loss(&scratch, label);
                params[idx] = orig - h;
                net.forward(&params.as_slice(), &img, &mut scratch, None);
                let lm = net.loss(&scratch, label);
                params[idx] = orig;
                let fd = (lp - lm) / (2.0 * h);
                let an = analytic[idx];
                if (fd - an).abs() > 6e-3 + 0.06 * fd.abs().max(an.abs()) {
                    return Err(format!(
                        "gradcheck failed at param {idx}: fd={fd} analytic={an} (arch {:?})",
                        arch.layers
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shared_store_publications_sum_exactly() {
    // Linearizability of the controlled scheme: concurrent per-layer
    // publications never lose updates, for random layer choices and
    // publication counts.
    run(
        Config { cases: 12, max_size: 8, seed: 0xBEEF },
        |rng, size| {
            let threads = 2 + rng.range(0, 6);
            let pubs = 20 + rng.range(0, 50 * size);
            (threads, pubs, rng.next_u64())
        },
        |&(threads, pubs, seed)| {
            let arch = ArchSpec::tiny();
            let dims = compute_dims(&arch);
            let total = chaos_phi::nn::total_params(&dims);
            let store = SharedParams::new(&vec![0.0; total], &dims);
            let layer = 1; // first conv layer
            let range = dims[layer].params.clone();
            std::thread::scope(|s| {
                for t in 0..threads {
                    let store = &store;
                    let range = range.clone();
                    s.spawn(move || {
                        let mut rng = Pcg32::new(seed, t as u64);
                        let grads: Vec<f32> = (0..range.len()).map(|_| rng.next_f32()).collect();
                        // integers scaled: use 1.0 per publish for exactness
                        let ones = vec![1.0f32; grads.len()];
                        for _ in 0..pubs {
                            store.publish_scaled(layer, range.clone(), &ones, 1.0);
                        }
                    });
                }
            });
            let expect = (threads * pubs) as f32;
            for i in range {
                if store.get(i) != expect {
                    return Err(format!("element {i}: {} != {expect}", store.get(i)));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sampler_is_an_exact_partition() {
    run(
        Config { cases: 16, max_size: 10, seed: 0x5A11 },
        |rng, size| {
            let n = 10 + rng.range(0, 200 * size);
            let threads = 1 + rng.range(0, 8);
            let epoch = rng.range(0, 5);
            (n, threads, epoch as usize)
        },
        |&(n, threads, epoch)| {
            let s = Sampler::shuffled(n, 42, epoch);
            let counts: Vec<usize> = std::thread::scope(|scope| {
                let hs: Vec<_> = (0..threads)
                    .map(|_| {
                        scope.spawn(|| {
                            let mut mine = std::collections::HashSet::new();
                            while let Some(i) = s.next() {
                                if !mine.insert(i) {
                                    panic!("duplicate within a thread");
                                }
                            }
                            mine.len()
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let total: usize = counts.iter().sum();
            if total != n {
                return Err(format!("issued {total} of {n} images"));
            }
            Ok(())
        },
    );
}

#[test]
fn simulator_time_monotone_in_work() {
    run(
        Config { cases: 10, max_size: 8, seed: 0x7137 },
        |rng, _| {
            let arch = ["small", "medium", "large"][rng.range(0, 3)];
            let p = [1, 15, 30, 60, 120, 240][rng.range(0, 6)];
            (arch, p)
        },
        |&(arch, p)| {
            let base = SimConfig { epochs: 2, ..SimConfig::paper(arch, p) };
            let more_images = SimConfig { images: base.images * 2, ..base.clone() };
            let more_epochs = SimConfig { epochs: 4, ..base.clone() };
            let t = simulate(&base).map_err(|e| e.to_string())?.total_secs();
            let ti = simulate(&more_images).map_err(|e| e.to_string())?.total_secs();
            let te = simulate(&more_epochs).map_err(|e| e.to_string())?.total_secs();
            if ti <= t {
                return Err(format!("{arch}@{p}: 2x images not slower ({ti} <= {t})"));
            }
            if te <= t {
                return Err(format!("{arch}@{p}: 2x epochs not slower ({te} <= {t})"));
            }
            Ok(())
        },
    );
}

#[test]
fn perfmodel_monotone_in_images_and_epochs() {
    run(
        Config { cases: 16, max_size: 8, seed: 0xD00D },
        |rng, _| {
            let arch = ["small", "medium", "large"][rng.range(0, 3)];
            let p = 1 + rng.range(0, 4000);
            (arch, p)
        },
        |&(arch, p)| {
            let m = PerfModel::for_arch(arch).map_err(|e| e.to_string())?;
            let base = Scenario::paper_default(arch, p);
            let t = m.predict_secs(&base);
            let t2 = m.predict_secs(&Scenario { images: base.images * 2, ..base });
            let t3 = m.predict_secs(&Scenario { epochs: base.epochs * 2, ..base });
            if !(t2 > t && t3 > t && t > 0.0) {
                return Err(format!("monotonicity violated at {arch}@{p}: {t} {t2} {t3}"));
            }
            Ok(())
        },
    );
}

#[test]
fn forward_probs_always_a_distribution() {
    run(
        Config { cases: 12, max_size: 6, seed: 0xABCD },
        |rng, size| {
            let arch = random_arch(rng, size);
            (arch, rng.next_u64())
        },
        |(arch, seed)| {
            if arch.validate().is_err() {
                return Ok(());
            }
            let net = Network::new(arch.clone());
            let params = net.init_params(*seed);
            let mut scratch = net.scratch();
            let side = arch.input_side();
            let mut rng = Pcg32::seeded(*seed);
            let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let probs = net.forward(&params.as_slice(), &img, &mut scratch, None).to_vec();
            let sum: f32 = probs.iter().sum();
            check_close(&[sum], &[1.0], 1e-4, 0.0)?;
            if probs.iter().any(|&p| !(0.0..=1.0).contains(&p)) {
                return Err(format!("probs out of range: {probs:?}"));
            }
            Ok(())
        },
    );
}
