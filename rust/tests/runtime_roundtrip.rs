//! Cross-validation of the two engines: the native rust `nn` stack against
//! the AOT JAX/Pallas artifacts executed through PJRT. Both implement the
//! same math over the same flat parameter vector, so probabilities, losses
//! and gradients must agree to float tolerance.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use chaos_phi::nn::Network;
use chaos_phi::runtime::{
    artifacts_available, BatchForwardEngine, ForwardEngine, Manifest, Runtime, TrainEngine,
};
use chaos_phi::util::Pcg32;

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn skip_unless_built() -> Option<(Manifest, Runtime)> {
    let dir = artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest parses");
    let rt = Runtime::cpu().expect("pjrt cpu client");
    Some((manifest, rt))
}

fn rand_image(rng: &mut Pcg32, side: usize) -> Vec<f32> {
    (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn forward_probs_match_native_engine() {
    let Some((manifest, rt)) = skip_unless_built() else { return };
    for arch_name in ["tiny", "small"] {
        if manifest.arch(arch_name).is_err() {
            continue;
        }
        let engine = ForwardEngine::load(&rt, &manifest, arch_name).unwrap();
        let net = Network::from_name(arch_name).unwrap();
        assert_eq!(engine.arch.param_count, net.total_params);

        let params = net.init_params(0xAB);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(17);
        for trial in 0..3 {
            let img = rand_image(&mut rng, engine.arch.input_side);
            let hlo_probs = engine.run(&params, &img).unwrap();
            let native = net.forward(&params.as_slice(), &img, &mut scratch, None);
            let d = max_abs_diff(&hlo_probs, native);
            assert!(
                d < 2e-5,
                "{arch_name} trial {trial}: probs diverge by {d}"
            );
        }
    }
}

#[test]
fn train_step_matches_native_gradients() {
    let Some((manifest, rt)) = skip_unless_built() else { return };
    let arch_name = "tiny";
    if manifest.arch(arch_name).is_err() {
        eprintln!("SKIP: tiny not in manifest");
        return;
    }
    let engine = TrainEngine::load(&rt, &manifest, arch_name).unwrap();
    let net = Network::from_name(arch_name).unwrap();
    let params = net.init_params(0xCD);
    let mut scratch = net.scratch();
    let mut rng = Pcg32::seeded(23);
    let img = rand_image(&mut rng, engine.arch.input_side);
    let label = 6usize;

    let out = engine.run(&params, &img, label as i32).unwrap();

    let native_probs =
        net.forward(&params.as_slice(), &img, &mut scratch, None).to_vec();
    let native_loss = net.loss(&scratch, label);
    let mut native_grads = vec![0.0f32; net.total_params];
    net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, g| {
        native_grads[d.params.clone()].copy_from_slice(g);
    });

    assert!(
        (out.loss - native_loss).abs() < 1e-4,
        "loss: hlo {} vs native {}",
        out.loss,
        native_loss
    );
    assert!(max_abs_diff(&out.probs, &native_probs) < 2e-5, "probs diverge");
    let gd = max_abs_diff(&out.grads, &native_grads);
    assert!(gd < 5e-4, "gradients diverge by {gd}");
    assert_eq!(out.grads.len(), net.total_params);
}

#[test]
fn batched_forward_matches_singles() {
    let Some((manifest, rt)) = skip_unless_built() else { return };
    let arch_name = "tiny";
    if manifest.arch(arch_name).is_err() {
        eprintln!("SKIP: tiny not in manifest");
        return;
    }
    let batched = BatchForwardEngine::load(&rt, &manifest, arch_name).unwrap();
    let single = ForwardEngine::load(&rt, &manifest, arch_name).unwrap();
    let net = Network::from_name(arch_name).unwrap();
    let params = net.init_params(0xEF);
    let side = batched.arch.input_side;
    let mut rng = Pcg32::seeded(31);

    // Fill a whole batch with random images.
    let b = batched.batch;
    let mut images = Vec::with_capacity(b * side * side);
    for _ in 0..b {
        images.extend(rand_image(&mut rng, side));
    }
    let rows = batched.run(&params, &images).unwrap();
    assert_eq!(rows.len(), b);
    for (i, row) in rows.iter().enumerate() {
        let img = &images[i * side * side..(i + 1) * side * side];
        let one = single.run(&params, img).unwrap();
        let d = max_abs_diff(row, &one);
        assert!(d < 2e-5, "batch row {i} diverges by {d}");
    }
}

#[test]
fn sgd_on_hlo_gradients_reduces_loss() {
    // The AOT train-step is a drop-in gradient source: a few steps of SGD
    // using only PJRT-produced gradients must reduce the loss.
    let Some((manifest, rt)) = skip_unless_built() else { return };
    if manifest.arch("tiny").is_err() {
        eprintln!("SKIP: tiny not in manifest");
        return;
    }
    let engine = TrainEngine::load(&rt, &manifest, "tiny").unwrap();
    let net = Network::from_name("tiny").unwrap();
    let mut params = net.init_params(0x11);
    let mut rng = Pcg32::seeded(41);
    let img = rand_image(&mut rng, engine.arch.input_side);
    let label = 3;

    let first = engine.run(&params, &img, label).unwrap().loss;
    let mut last = first;
    for _ in 0..10 {
        let out = engine.run(&params, &img, label).unwrap();
        for (w, g) in params.iter_mut().zip(&out.grads) {
            *w -= 0.1 * g;
        }
        last = out.loss;
    }
    assert!(
        last < first * 0.5,
        "HLO-gradient SGD failed to overfit one sample: {first} -> {last}"
    );
}
