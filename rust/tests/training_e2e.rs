//! End-to-end training integration: data generation → CHAOS coordinator →
//! reporter, across policies and architectures, plus failure-mode
//! coverage (bad configs). All entry points go through the `Trainer`
//! builder; `deprecated_shim.rs`-style back-compat for the old free
//! function lives in `trainer_api.rs`.

use chaos_phi::chaos::{ChaosPolicy, SequentialPolicy, Trainer};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, load_or_generate, SynthConfig};
use chaos_phi::nn::Network;

fn cfg(threads: usize, epochs: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        threads,
        eta0: 0.01,
        eta_decay: 0.9,
        seed: 77,
        validation_fraction: 0.2,
        eval_batch: 32,
        ..TrainConfig::default()
    }
}

#[test]
fn small_arch_learns_synthetic_digits() {
    let net = Network::new(ArchSpec::small());
    let (train_set, test_set) = load_or_generate("data/mnist", 600, 200, 7);
    let run = Trainer::new()
        .network(net)
        .config(cfg(1, 3))
        .policy(SequentialPolicy)
        .run(&train_set, &test_set)
        .unwrap();
    let first = &run.epochs[0];
    let last = run.final_epoch();
    assert!(last.train.loss < first.train.loss * 0.8, "loss must fall substantially");
    assert!(
        last.test.error_rate() < 0.35,
        "test error rate {} too high after 3 epochs",
        last.test.error_rate()
    );
}

#[test]
fn chaos_accuracy_parity_on_small_arch() {
    // The Result-4 experiment at integration scale: same seed/data, CHAOS
    // at 4 workers vs sequential; final error rates must be comparable.
    let net = Network::new(ArchSpec::small());
    let (train_set, test_set) = load_or_generate("data/mnist", 500, 200, 9);
    let seq = Trainer::new()
        .network(net.clone())
        .config(cfg(1, 2))
        .policy(SequentialPolicy)
        .run(&train_set, &test_set)
        .unwrap();
    let par = Trainer::new()
        .network(net)
        .config(cfg(4, 2))
        .policy(ChaosPolicy)
        .run(&train_set, &test_set)
        .unwrap();
    let d = (seq.final_epoch().test.error_rate() - par.final_epoch().test.error_rate()).abs();
    assert!(
        d < 0.12,
        "parity gap {d}: seq {} vs chaos {}",
        seq.final_epoch().test.error_rate(),
        par.final_epoch().test.error_rate()
    );
    // CHAOS must actually publish per parameterized layer: 4 per sample
    // per epoch (small arch has 4 parameterized layers).
    let expected = (train_set.len() * 2 * 4) as u64;
    assert_eq!(par.publications, expected);
}

#[test]
fn epoch_metrics_account_every_image() {
    let net = Network::new(ArchSpec::tiny());
    let train_set = generate_synthetic(150, 3, &SynthConfig::default()).resize(13);
    let test_set = generate_synthetic(50, 4, &SynthConfig::default()).resize(13);
    for name in ["chaos", "hogwild", "averaged:8"] {
        let run = Trainer::new()
            .network(net.clone())
            .config(cfg(3, 2))
            .policy_name(name)
            .unwrap()
            .run(&train_set, &test_set)
            .unwrap();
        for e in &run.epochs {
            assert_eq!(e.train.images, 150, "{name}");
            assert_eq!(e.validation.images, 30, "{name}");
            assert_eq!(e.test.images, 50, "{name}");
        }
        assert_eq!(run.epochs.len(), 2);
        assert_eq!(run.final_params.len(), net.total_params);
    }
}

#[test]
fn run_result_round_trips_through_json_file() {
    let net = Network::new(ArchSpec::tiny());
    let train_set = generate_synthetic(60, 5, &SynthConfig::default()).resize(13);
    let test_set = generate_synthetic(30, 6, &SynthConfig::default()).resize(13);
    let run = Trainer::new()
        .network(net)
        .config(cfg(2, 1))
        .policy(ChaosPolicy)
        .run(&train_set, &test_set)
        .unwrap();
    let path = std::env::temp_dir().join(format!("chaos_run_{}.json", std::process::id()));
    run.save(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = chaos_phi::util::Json::parse(&text).unwrap();
    assert_eq!(j.get("arch").unwrap().as_str(), Some("tiny"));
    assert_eq!(j.get("threads").unwrap().as_usize(), Some(2));
    assert_eq!(j.get("epochs").unwrap().as_arr().unwrap().len(), 1);
    assert_eq!(j.get("stopped_early").unwrap().as_bool(), Some(false));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn invalid_configs_rejected() {
    let net = Network::new(ArchSpec::tiny());
    let d = generate_synthetic(10, 1, &SynthConfig::default()).resize(13);
    for bad in [
        TrainConfig { epochs: 0, ..cfg(1, 1) },
        TrainConfig { threads: 0, ..cfg(1, 1) },
        TrainConfig { eta0: 0.0, ..cfg(1, 1) },
        TrainConfig { eta_decay: 0.0, ..cfg(1, 1) },
        TrainConfig { validation_fraction: 2.0, ..cfg(1, 1) },
    ] {
        let r = Trainer::new()
            .network(net.clone())
            .config(bad)
            .policy(ChaosPolicy)
            .run(&d, &d);
        assert!(r.is_err());
    }
}

#[test]
fn large_arch_single_step_is_finite() {
    // The large net is too slow for a full integration epoch in debug
    // builds; one SGD step proves the stack composes at full depth.
    let net = Network::new(ArchSpec::large());
    let mut params = net.init_params(1);
    let mut scratch = net.scratch();
    let img = generate_synthetic(1, 2, &SynthConfig::default());
    let (loss, _) = net.sgd_step(&mut params, img.image(0), 5, 0.001, &mut scratch, None);
    assert!(loss.is_finite() && loss > 0.0);
    assert!(params.iter().all(|w| w.is_finite()));
}
