//! Serving integration: dynamic batcher under concurrent clients.
//!
//! The native-engine tests run in every build (no artifacts needed) and
//! cover correctness against per-sample forwards, partial batches, the
//! `max_delay` straggler path, spawn-time validation, and the
//! drop-while-handles-alive detach. The PJRT tests require
//! `make artifacts` and skip otherwise.

use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::runtime::{artifacts_available, ForwardEngine, Manifest, Runtime};
use chaos_phi::serve::{Engine, Server, ServerConfig};
use std::time::Duration;

fn tiny_server(batch: usize, max_delay: Duration, seed: u64) -> (Server, Network, Vec<f32>) {
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(seed);
    let server = Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch },
        ServerConfig { max_delay, ..Default::default() },
    )
    .unwrap();
    (server, net, params)
}

#[test]
fn native_server_matches_per_sample_forward_under_concurrency() {
    let (server, net, params) = tiny_server(4, Duration::from_millis(1), 3);
    let images = generate_synthetic(24, 8, &SynthConfig::default()).resize(13);
    // Ground truth via the per-sample engine (bit-identity contract).
    let mut scratch = net.scratch();
    let expected: Vec<Vec<f32>> = (0..images.len())
        .map(|i| net.forward(&params.as_slice(), images.image(i), &mut scratch, None).to_vec())
        .collect();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    assert_eq!(got, expected[i], "batched vs per-sample mismatch on image {i}");
                    i += 3;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 24);
    assert!(m.batches >= 6, "batch cap is 4, so ≥6 batches for 24 requests");
    assert!(m.mean_batch_fill <= 4.0);
}

#[test]
fn native_server_flushes_partial_batch_after_max_delay() {
    // One lone request against a cap-8 batcher: the straggler timer (not a
    // full batch) must flush it.
    let (server, net, params) = tiny_server(8, Duration::from_millis(20), 5);
    let images = generate_synthetic(1, 4, &SynthConfig::default()).resize(13);
    let start = std::time::Instant::now();
    let probs = server.handle().predict(images.image(0)).unwrap();
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "partial batch must flush on max_delay, not wait for batch-mates"
    );
    let mut scratch = net.scratch();
    let expected = net.forward(&params.as_slice(), images.image(0), &mut scratch, None);
    assert_eq!(probs.as_slice(), expected, "partial batch row diverged");
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 1);
    assert_eq!(m.batches, 1);
    assert!(m.mean_batch_fill <= 1.0 + 1e-9, "lone request ⇒ batch of 1");
}

#[test]
fn native_server_rejects_wrong_image_size() {
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 1);
    let err = server.handle().predict(&[0.0; 10]).unwrap_err();
    assert!(err.to_string().contains("size"), "{err}");
}

#[test]
fn dropping_server_with_live_handles_detaches() {
    // Regression: Server::drop used to join unconditionally, deadlocking
    // whenever an external ServerHandle outlived the Server. Now it must
    // detach, and the surviving handle keeps being served.
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 2);
    let handle = server.handle();
    let images = generate_synthetic(2, 6, &SynthConfig::default()).resize(13);

    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("Server::drop must not block while external handles are alive");

    // The detached worker is still serving the surviving handle.
    let probs = handle.predict(images.image(0)).unwrap();
    assert_eq!(probs.len(), 10);
    drop(handle); // last sender gone → detached worker exits on its own
}

#[test]
fn dropping_server_without_handles_joins_worker() {
    // The complementary path: no external handles ⇒ drop joins promptly.
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 2);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("Server::drop must join once no handles remain");
}

#[test]
fn spawn_validation_rejects_degenerate_configs() {
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(1);
    assert!(Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch: 0 },
        ServerConfig::default(),
    )
    .is_err());
    assert!(Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch: 4 },
        ServerConfig { queue_depth: 0, ..Default::default() },
    )
    .is_err());
    // Parameter snapshot that does not match the network layout.
    assert!(Server::spawn(
        Engine::Native { net, params: vec![0.0; 5], batch: 4 },
        ServerConfig::default(),
    )
    .is_err());
}

// ---------------------------------------------------------------------------
// PJRT-engine tests (need `make artifacts`; skip otherwise)
// ---------------------------------------------------------------------------

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn skip() -> bool {
    if !artifacts_available(&artifact_dir()) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn pjrt_server_answers_concurrent_clients_correctly() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(3);
    let server = Server::spawn(
        Engine::Pjrt { artifact_dir: artifact_dir(), arch: "tiny".into(), params: params.clone() },
        ServerConfig { max_delay: Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();

    // Ground truth via the single-image engine, precomputed on this thread
    // (the PJRT handles are !Sync).
    let manifest = Manifest::load(artifact_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let single = ForwardEngine::load(&rt, &manifest, "tiny").unwrap();

    let images = generate_synthetic(24, 8, &SynthConfig::default()).resize(13);
    let expected: Vec<Vec<f32>> =
        (0..images.len()).map(|i| single.run(&params, images.image(i)).unwrap()).collect();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    for (a, b) in got.iter().zip(&expected[i]) {
                        assert!(
                            (a - b).abs() < 2e-5,
                            "batched vs single mismatch on image {i}"
                        );
                    }
                    i += 3;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 24);
}

#[test]
fn pjrt_server_load_error_is_reported() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let r = Server::spawn(
        Engine::Pjrt {
            artifact_dir: "/nonexistent/artifacts".into(),
            arch: "tiny".into(),
            params: net.init_params(1),
        },
        ServerConfig::default(),
    );
    assert!(r.is_err(), "missing artifact dir must fail spawn");
}
