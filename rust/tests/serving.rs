//! Serving integration: the multi-worker dynamic batcher under concurrent
//! clients.
//!
//! The native-engine tests run in every build (no artifacts needed) and
//! cover correctness against per-sample forwards (single- and
//! multi-worker pools), partial batches, the `max_delay` straggler path,
//! spawn-time validation, drop/detach semantics under load, typed
//! admission control (`Overloaded`), deadline expiry (expired requests
//! provably never reach the engine — enforced with a runtime-registered
//! "sleep" layer that wedges the worker deterministically), and the
//! live-from-training shared-store path. The whole suite also runs under
//! `--features race-check` in CI: the shared-store read path must satisfy
//! the training policy's `SyncContract`. The PJRT tests require
//! `make artifacts` and skip otherwise.

use chaos_phi::chaos::{SharedParams, Trainer};
use chaos_phi::config::{ArchSpec, LayerSpec};
use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::layer::{self, LayerCtx, LayerKind};
use chaos_phi::nn::{Acts, LayerDims, LayerOp, Network, OpScratch, Shape};
use chaos_phi::runtime::{artifacts_available, ForwardEngine, Manifest, NativeBatchEngine, Runtime};
use chaos_phi::serve::{Engine, ServeError, Server, ServerConfig};
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_server(batch: usize, max_delay: Duration, seed: u64) -> (Server, Network, Vec<f32>) {
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(seed);
    let server = Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch },
        ServerConfig { max_delay, ..Default::default() },
    )
    .unwrap();
    (server, net, params)
}

/// Poll `cond` (typically a metrics read) until true or `timeout`.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

#[test]
fn native_server_matches_per_sample_forward_under_concurrency() {
    let (server, net, params) = tiny_server(4, Duration::from_millis(1), 3);
    let images = generate_synthetic(24, 8, &SynthConfig::default()).resize(13);
    // Ground truth via the per-sample engine (bit-identity contract).
    let mut scratch = net.scratch();
    let expected: Vec<Vec<f32>> = (0..images.len())
        .map(|i| net.forward(&params.as_slice(), images.image(i), &mut scratch, None).to_vec())
        .collect();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    assert_eq!(got, expected[i], "batched vs per-sample mismatch on image {i}");
                    i += 3;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 24);
    assert!(m.batches >= 6, "batch cap is 4, so ≥6 batches for 24 requests");
    assert!(m.mean_batch_fill <= 4.0);
}

#[test]
fn native_server_flushes_partial_batch_after_max_delay() {
    // One lone request against a cap-8 batcher: the straggler timer (not a
    // full batch) must flush it.
    let (server, net, params) = tiny_server(8, Duration::from_millis(20), 5);
    let images = generate_synthetic(1, 4, &SynthConfig::default()).resize(13);
    let start = std::time::Instant::now();
    let probs = server.handle().predict(images.image(0)).unwrap();
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "partial batch must flush on max_delay, not wait for batch-mates"
    );
    let mut scratch = net.scratch();
    let expected = net.forward(&params.as_slice(), images.image(0), &mut scratch, None);
    assert_eq!(probs.as_slice(), expected, "partial batch row diverged");
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 1);
    assert_eq!(m.batches, 1);
    assert!(m.mean_batch_fill <= 1.0 + 1e-9, "lone request ⇒ batch of 1");
}

#[test]
fn native_server_rejects_wrong_image_size() {
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 1);
    let err = server.handle().predict(&[0.0; 10]).unwrap_err();
    assert!(err.to_string().contains("size"), "{err}");
}

#[test]
fn dropping_server_with_live_handles_detaches() {
    // Regression: Server::drop used to join unconditionally, deadlocking
    // whenever an external ServerHandle outlived the Server. Now it must
    // detach, and the surviving handle keeps being served.
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 2);
    let handle = server.handle();
    let images = generate_synthetic(2, 6, &SynthConfig::default()).resize(13);

    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("Server::drop must not block while external handles are alive");

    // The detached worker is still serving the surviving handle.
    let probs = handle.predict(images.image(0)).unwrap();
    assert_eq!(probs.len(), 10);
    drop(handle); // last sender gone → detached worker exits on its own
}

#[test]
fn dropping_server_without_handles_joins_worker() {
    // The complementary path: no external handles ⇒ drop joins promptly.
    let (server, _, _) = tiny_server(4, Duration::from_millis(1), 2);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("Server::drop must join once no handles remain");
}

#[test]
fn spawn_validation_rejects_degenerate_configs() {
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(1);
    assert!(Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch: 0 },
        ServerConfig::default(),
    )
    .is_err());
    assert!(Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch: 4 },
        ServerConfig { queue_depth: 0, ..Default::default() },
    )
    .is_err());
    // Parameter snapshot that does not match the network layout.
    assert!(Server::spawn(
        Engine::Native { net, params: vec![0.0; 5], batch: 4 },
        ServerConfig::default(),
    )
    .is_err());
}

#[test]
fn multi_worker_pool_matches_per_sample_forward() {
    // N ≥ 2 workers, each with its own engine/arenas, racing over one
    // queue: every row must still be bit-identical to the per-sample
    // reference, whichever worker served it.
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(9);
    let server = Server::spawn(
        Engine::Native { net: net.clone(), params: params.clone(), batch: 4 },
        ServerConfig { max_delay: Duration::from_millis(1), workers: 3, ..Default::default() },
    )
    .unwrap();
    let images = generate_synthetic(48, 8, &SynthConfig::default()).resize(13);
    let mut scratch = net.scratch();
    let expected: Vec<Vec<f32>> = (0..images.len())
        .map(|i| net.forward(&params.as_slice(), images.image(i), &mut scratch, None).to_vec())
        .collect();
    std::thread::scope(|s| {
        for c in 0..6usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    assert_eq!(got, expected[i], "pool served a wrong row for image {i}");
                    i += 6;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 48);
    assert_eq!(m.workers, 3);
    assert!(m.batches >= 12, "cap 4 ⇒ at least 12 batches for 48 requests");
}

// ---------------------------------------------------------------------------
// Deterministic load tests: a runtime-registered "sleep" pass-through layer
// wedges the worker for a known duration, so queue-full and deadline-expiry
// scenarios need no timing luck.
// ---------------------------------------------------------------------------

/// How long one sleepnet forward wedges its worker.
const SLEEP_MS: u64 = 250;

struct SleepKind;

#[derive(Debug)]
struct SleepOp {
    shape: Shape,
}

impl LayerKind for SleepKind {
    fn name(&self) -> &'static str {
        "sleep"
    }

    fn from_json(&self, _body: &chaos_phi::util::Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::custom("sleep", vec![]))
    }

    fn to_json(&self, _spec: &LayerSpec) -> chaos_phi::util::Json {
        chaos_phi::util::Json::obj(vec![])
    }

    fn out_shape(
        &self,
        _spec: &LayerSpec,
        input: Shape,
        _ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        Ok(input)
    }

    fn compile(&self, _spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        Ok(Box::new(SleepOp {
            shape: Shape { maps: dims.out_maps, side: dims.out_side, flat: dims.flat },
        }))
    }
}

impl LayerOp for SleepOp {
    fn kind(&self) -> &'static str {
        "sleep"
    }

    fn in_shape(&self) -> Shape {
        self.shape
    }

    fn out_shape(&self) -> Shape {
        self.shape
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        std::thread::sleep(Duration::from_millis(SLEEP_MS));
        out.copy_from_slice(input);
    }

    fn backward(
        &self,
        _: &[f32],
        _acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return;
        }
        delta_in.copy_from_slice(delta_out);
    }
}

/// One worker, batch cap 1, on a network whose forward sleeps `SLEEP_MS`.
fn sleepy_server(queue_depth: usize) -> Server {
    // Ignore the duplicate error when the test binary registers twice.
    let _ = layer::register(Arc::new(SleepKind));
    let arch = ArchSpec {
        name: "sleepnet".into(),
        layers: vec![
            LayerSpec::Input { side: 13 },
            LayerSpec::custom("sleep", vec![]),
            LayerSpec::fc(8),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    };
    let net = Network::compile(arch).unwrap();
    let params = net.init_params(1);
    Server::spawn(
        Engine::Native { net, params, batch: 1 },
        ServerConfig { max_delay: Duration::from_micros(1), queue_depth, workers: 1 },
    )
    .unwrap()
}

#[test]
fn full_queue_yields_typed_overloaded_rejection() {
    // queue_depth 1, one wedged worker: A executes (in-flight), B occupies
    // the only queue slot, so C's try_predict must be rejected with the
    // typed Overloaded — immediately, not by blocking.
    let server = sleepy_server(1);
    let image = vec![0.0f32; 13 * 13];
    let h = server.handle();

    let ha = server.handle();
    let img_a = image.clone();
    let ta = std::thread::spawn(move || ha.predict(&img_a));
    // A is staged in the engine (in-flight gauge) ⇒ the queue is empty.
    assert!(
        wait_until(Duration::from_secs(10), || h.metrics.snapshot().in_flight >= 1),
        "worker never staged the first request"
    );

    let hb = server.handle();
    let img_b = image.clone();
    let tb = std::thread::spawn(move || hb.predict(&img_b));
    // B admitted ⇒ the queue is now full.
    assert!(
        wait_until(Duration::from_secs(10), || h.metrics.snapshot().queue_depth >= 1),
        "second request never reached the queue"
    );

    let start = Instant::now();
    let err = h.try_predict(&image).unwrap_err();
    assert_eq!(err, ServeError::Overloaded);
    assert!(
        start.elapsed() < Duration::from_millis(SLEEP_MS),
        "try_predict must reject immediately, not wait out the wedged worker"
    );

    assert_eq!(ta.join().unwrap().unwrap().len(), 10);
    assert_eq!(tb.join().unwrap().unwrap().len(), 10);
    let m = h.metrics.snapshot();
    assert_eq!(m.overloaded, 1);
    assert_eq!(m.requests, 2);
}

#[test]
fn expired_requests_never_reach_the_engine() {
    // A wedges the worker for SLEEP_MS; B and C carry deadlines that
    // expire long before the worker frees up. Both clients must get the
    // typed Expired, and the engine must run exactly one batch (cap 1 ⇒
    // batches == executions): the expired requests were cancelled at the
    // admit gate, never staged.
    let server = sleepy_server(8);
    let image = vec![0.0f32; 13 * 13];
    let h = server.handle();

    let ha = server.handle();
    let img_a = image.clone();
    let ta = std::thread::spawn(move || ha.predict(&img_a));
    assert!(
        wait_until(Duration::from_secs(10), || h.metrics.snapshot().in_flight >= 1),
        "worker never staged the first request"
    );

    let deadline = Duration::from_millis(SLEEP_MS / 4);
    let clients: Vec<_> = (0..2)
        .map(|_| {
            let hx = server.handle();
            let img = image.clone();
            std::thread::spawn(move || hx.predict_deadline(&img, deadline))
        })
        .collect();
    for c in clients {
        assert_eq!(c.join().unwrap().unwrap_err(), ServeError::Expired);
    }
    assert_eq!(ta.join().unwrap().unwrap().len(), 10);

    // The worker discovers (and counts) both expiries once it unwedges.
    assert!(
        wait_until(Duration::from_secs(10), || h.metrics.snapshot().expired == 2),
        "worker must count both expired requests"
    );
    let m = h.metrics.snapshot();
    assert_eq!(m.requests, 1, "only the deadline-free request was served");
    assert_eq!(m.batches, 1, "cap 1 ⇒ one batch per execution; expired requests never ran");
}

#[test]
fn worker_pool_shutdown_joins_all_workers() {
    // A 4-worker pool with no external handles: drop must close the queue,
    // wake every idle worker, and join all of them promptly.
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(2);
    let server = Server::spawn(
        Engine::Native { net, params, batch: 4 },
        ServerConfig { max_delay: Duration::from_millis(1), workers: 4, ..Default::default() },
    )
    .unwrap();
    // Touch the pool so workers are demonstrably alive before shutdown.
    let images = generate_synthetic(8, 3, &SynthConfig::default()).resize(13);
    for i in 0..images.len() {
        assert_eq!(server.handle().predict(images.image(i)).unwrap().len(), 10);
    }
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("Server::drop must join all 4 workers once no handles remain");
}

#[test]
fn dropping_server_under_load_keeps_serving_surviving_handles() {
    // Clients submit continuously while the Server drops mid-stream: the
    // pool must detach (handles outlive it) and every in-flight and
    // subsequent request must still be answered — no hang, no Stopped.
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(4);
    let server = Server::spawn(
        Engine::Native { net, params, batch: 4 },
        ServerConfig { max_delay: Duration::from_micros(200), workers: 2, ..Default::default() },
    )
    .unwrap();
    let images = generate_synthetic(30, 5, &SynthConfig::default()).resize(13);
    let handles: Vec<_> = (0..3).map(|_| server.handle()).collect();
    std::thread::scope(|s| {
        for (c, handle) in handles.into_iter().enumerate() {
            let images = &images;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let row = handle
                        .predict(images.image(i))
                        .expect("detached pool must keep serving live handles");
                    assert_eq!(row.len(), 10);
                    i += 3;
                }
            });
        }
        // Drop the server while the clients above are mid-stream.
        std::thread::sleep(Duration::from_millis(2));
        drop(server);
    });
}

// ---------------------------------------------------------------------------
// Shared-store (live-from-training) serving
// ---------------------------------------------------------------------------

#[test]
fn shared_store_server_tracks_published_updates() {
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(7);
    let store = Arc::new(SharedParams::new(&params, &net.dims));
    let server = Server::spawn_shared(
        net.clone(),
        store.clone(),
        4,
        ServerConfig { max_delay: Duration::from_millis(1), workers: 2, ..Default::default() },
    )
    .unwrap();
    let images = generate_synthetic(4, 6, &SynthConfig::default()).resize(13);

    // Quiescent store ⇒ bit-identical to a frozen engine on the same
    // weights.
    let mut frozen = NativeBatchEngine::new(net.clone(), params, 1).unwrap();
    let live = server.handle().predict(images.image(0)).unwrap();
    assert_eq!(live, frozen.run(images.image(0), 1).unwrap()[0]);

    // Publish an update; the next prediction's per-batch snapshot must see
    // it.
    let range = net.dims[1].params.clone();
    store.publish_scaled(1, range.clone(), &vec![1.0; range.len()], 5.0);
    let mut updated = NativeBatchEngine::new(net, store.snapshot(), 1).unwrap();
    let live = server.handle().predict(images.image(0)).unwrap();
    assert_eq!(live, updated.run(images.image(0), 1).unwrap()[0]);
    assert_eq!(store.publication_count(), 1);
}

#[test]
fn live_from_training_server_serves_correct_predictions_mid_epoch() {
    // The capstone path, and the race-check gate: CHAOS trains while a
    // 2-worker pool serves from the same store. Mid-epoch rows must be
    // well-formed probabilities; once training stops publishing, the live
    // engine must agree bit-for-bit with the run's final weights. Under
    // `--features race-check` the trainer additionally asserts the store
    // is defect-free at the end of the run — serving reads included.
    let train_set = generate_synthetic(300, 1, &SynthConfig::default()).resize(13);
    let test_set = generate_synthetic(50, 2, &SynthConfig::default()).resize(13);
    let queries = generate_synthetic(16, 3, &SynthConfig::default()).resize(13);

    let (store_tx, store_rx) = std::sync::mpsc::channel();
    let trainer = Trainer::new()
        .arch(ArchSpec::tiny())
        .epochs(2)
        .threads(3)
        .eta(0.05, 0.95)
        .seed(42)
        .export_store(store_tx);
    let training = std::thread::spawn(move || trainer.run(&train_set, &test_set));
    let store = store_rx.recv().expect("parallel run must export its store");

    let net = Network::from_name("tiny").unwrap();
    let server = Server::spawn_shared(
        net.clone(),
        store,
        4,
        ServerConfig { max_delay: Duration::from_micros(200), workers: 2, ..Default::default() },
    )
    .unwrap();
    let handle = server.handle();

    // At least one full pass runs unconditionally (the store is live from
    // before epoch 0); subsequent passes keep querying until training ends.
    let mut served_live = 0usize;
    loop {
        let still_training = !training.is_finished();
        for i in 0..queries.len() {
            let row = handle.predict(queries.image(i)).unwrap();
            assert_eq!(row.len(), 10);
            let sum: f32 = row.iter().sum();
            assert!(
                row.iter().all(|p| p.is_finite() && *p >= 0.0) && (sum - 1.0).abs() < 1e-3,
                "malformed probability row mid-training (sum {sum})"
            );
            served_live += 1;
        }
        if !still_training {
            break;
        }
    }
    let run = training.join().unwrap().unwrap();
    assert!(run.publications > 0, "parallel training must publish");
    assert!(served_live >= queries.len(), "live queries must be served against the store");

    // Training stopped ⇒ live store == final weights, bit for bit.
    let mut frozen = NativeBatchEngine::new(net, run.final_params.clone(), 1).unwrap();
    for i in 0..queries.len() {
        let live = handle.predict(queries.image(i)).unwrap();
        assert_eq!(live, frozen.run(queries.image(i), 1).unwrap()[0], "query {i} diverged");
    }
}

// ---------------------------------------------------------------------------
// PJRT-engine tests (need `make artifacts`; skip otherwise)
// ---------------------------------------------------------------------------

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn skip() -> bool {
    if !artifacts_available(&artifact_dir()) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn pjrt_server_answers_concurrent_clients_correctly() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(3);
    let server = Server::spawn(
        Engine::Pjrt { artifact_dir: artifact_dir(), arch: "tiny".into(), params: params.clone() },
        ServerConfig { max_delay: Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();

    // Ground truth via the single-image engine, precomputed on this thread
    // (the PJRT handles are !Sync).
    let manifest = Manifest::load(artifact_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let single = ForwardEngine::load(&rt, &manifest, "tiny").unwrap();

    let images = generate_synthetic(24, 8, &SynthConfig::default()).resize(13);
    let expected: Vec<Vec<f32>> =
        (0..images.len()).map(|i| single.run(&params, images.image(i)).unwrap()).collect();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    for (a, b) in got.iter().zip(&expected[i]) {
                        assert!(
                            (a - b).abs() < 2e-5,
                            "batched vs single mismatch on image {i}"
                        );
                    }
                    i += 3;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 24);
}

#[test]
fn pjrt_server_load_error_is_reported() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let r = Server::spawn(
        Engine::Pjrt {
            artifact_dir: "/nonexistent/artifacts".into(),
            arch: "tiny".into(),
            params: net.init_params(1),
        },
        ServerConfig::default(),
    );
    assert!(r.is_err(), "missing artifact dir must fail spawn");
}
