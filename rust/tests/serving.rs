//! Serving integration: dynamic batcher + PJRT batched executor under
//! concurrent clients. Requires `make artifacts`; skips otherwise.

use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::runtime::{artifacts_available, ForwardEngine, Manifest, Runtime};
use chaos_phi::serve::{Server, ServerConfig};

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn skip() -> bool {
    if !artifacts_available(&artifact_dir()) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn server_answers_concurrent_clients_correctly() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(3);
    let server = Server::spawn(
        artifact_dir(),
        "tiny".into(),
        params.clone(),
        ServerConfig { max_delay: std::time::Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();

    // Ground truth via the single-image engine.
    let manifest = Manifest::load(artifact_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let single = ForwardEngine::load(&rt, &manifest, "tiny").unwrap();

    let images = generate_synthetic(24, 8, &SynthConfig::default()).resize(13);
    // Ground truth precomputed on this thread (the PJRT handles are !Sync).
    let expected: Vec<Vec<f32>> =
        (0..images.len()).map(|i| single.run(&params, images.image(i)).unwrap()).collect();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let handle = server.handle();
            let images = &images;
            let expected = &expected;
            s.spawn(move || {
                let mut i = c;
                while i < images.len() {
                    let got = handle.predict(images.image(i)).unwrap();
                    for (a, b) in got.iter().zip(&expected[i]) {
                        assert!(
                            (a - b).abs() < 2e-5,
                            "batched vs single mismatch on image {i}"
                        );
                    }
                    i += 3;
                }
            });
        }
    });
    let m = server.handle().metrics.snapshot();
    assert_eq!(m.requests, 24);
    assert!(m.batches >= 6, "batch cap is 4, so ≥6 batches for 24 requests");
    assert!(m.mean_batch_fill <= 4.0);
}

#[test]
fn server_rejects_wrong_image_size() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let server = Server::spawn(
        artifact_dir(),
        "tiny".into(),
        net.init_params(1),
        ServerConfig::default(),
    )
    .unwrap();
    let err = server.handle().predict(&[0.0; 10]).unwrap_err();
    assert!(err.to_string().contains("size"), "{err}");
}

#[test]
fn server_load_error_is_reported() {
    if skip() {
        return;
    }
    let net = Network::from_name("tiny").unwrap();
    let r = Server::spawn(
        "/nonexistent/artifacts".into(),
        "tiny".into(),
        net.init_params(1),
        ServerConfig::default(),
    );
    assert!(r.is_err(), "missing artifact dir must fail spawn");
}
