//! Integration coverage for the shard planner/verifier
//! (`chaos::analysis::shard`) through the crate's public API: every
//! planner-produced plan must verify clean across shard counts, paper
//! architectures and the shipped example arch files; per-shard cost
//! totals must cross-check the unsharded audit; and each seeded defect
//! class — straddled split point, partial replica, in-shard overlap,
//! gap — must be detected.

use chaos_phi::chaos::analysis::{
    plan_shards, plan_shards_weighted, verify_shards, LayerAssignment, ShardPlan,
};
use chaos_phi::config::ArchSpec;
use chaos_phi::nn::audit::audit_cost;
use chaos_phi::nn::Network;
use chaos_phi::util::proptest::{run, Config};

const PAPER_ARCHS: [&str; 4] = ["tiny", "small", "medium", "large"];

fn split_layers(plan: &ShardPlan) -> Vec<usize> {
    plan.layers
        .iter()
        .enumerate()
        .filter(|(_, a)| matches!(a, LayerAssignment::Split { .. }))
        .map(|(l, _)| l)
        .collect()
}

/// Clean plan + score invariants shared by every positive case below.
fn assert_plan_sound(net: &Network, plan: &ShardPlan) -> Result<(), String> {
    let report = verify_shards(net, plan);
    if !report.is_clean() {
        return Err(format!("{}: defects {:?}", plan.arch, report.defects));
    }
    let score = report.score.as_ref().ok_or("clean plan must carry a score")?;

    // Sharding moves work, it does not create any: fleet totals equal the
    // unsharded cost audit exactly.
    let audit = audit_cost(net, 1);
    for (got, want, what) in [
        (score.total_fwd_flops(), audit.total_fwd_flops(), "fwd"),
        (score.total_bwd_flops(), audit.total_bwd_flops(), "bwd"),
    ] {
        if (got - want).abs() > 1e-9 * want.max(1.0) {
            return Err(format!("{}: {what} flops {got} vs audit {want}", plan.arch));
        }
    }
    if score.imbalance < 1.0 - 1e-12 {
        return Err(format!("imbalance {} < 1", score.imbalance));
    }
    // (The reverse is not an invariant: a heavily skewed weighted plan may
    // hand one shard an entire fc span — one participant, no traffic.)
    if plan.shards == 1 && score.comm_bytes != 0.0 {
        return Err(format!("one shard but {} comm bytes", score.comm_bytes));
    }

    // Owned pieces partition each split span.
    for l in split_layers(plan) {
        let total: usize = (0..plan.shards).map(|s| plan.owned_len(net, s, l)).sum();
        if total != net.dims[l].params.len() {
            return Err(format!("layer {l}: owned {total} != span {}", net.dims[l].params.len()));
        }
    }
    Ok(())
}

#[test]
fn planner_plans_verify_clean_across_archs_and_shard_counts() {
    for arch in PAPER_ARCHS {
        let net = Network::from_name(arch).unwrap();
        for n in 1..=8 {
            let plan = plan_shards(&net, n);
            assert_plan_sound(&net, &plan).unwrap_or_else(|e| panic!("{arch}/{n}: {e}"));
            if n > 1 {
                // Uniform plans split every fc span across all shards, so
                // the boundary allgathers must price real traffic.
                let score = verify_shards(&net, &plan).score.unwrap();
                assert!(score.comm_bytes > 0.0, "{arch}/{n}: free multi-shard traffic");
            }
        }
    }
}

#[test]
fn example_arch_files_plan_clean() {
    let mut seen = 0;
    for entry in std::fs::read_dir("examples/archs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        seen += 1;
        let arch = ArchSpec::from_file(path.to_str().unwrap()).unwrap();
        let net = Network::compile(arch).unwrap();
        for n in [1, 2, 4, 8] {
            let plan = plan_shards(&net, n);
            assert_plan_sound(&net, &plan)
                .unwrap_or_else(|e| panic!("{}/{n}: {e}", path.display()));
        }
    }
    assert!(seen > 0, "no example arch files found (run tests from the repo root)");
}

/// Property: for random weight vectors over the paper archs, the weighted
/// planner's plan verifies clean, and heavier shards never own fewer
/// split parameters than lighter ones.
#[test]
fn weighted_plans_verify_clean_for_random_weights() {
    run(
        Config { cases: 48, max_size: 8, seed: 0x5AADD },
        |rng, size| {
            let arch = PAPER_ARCHS[rng.range(0, PAPER_ARCHS.len())];
            let shards = 1 + rng.range(0, size.max(1));
            let weights: Vec<f64> =
                (0..shards).map(|_| rng.uniform(0.1, 4.0) as f64).collect();
            (arch, weights)
        },
        |(arch, weights)| {
            let net = Network::from_name(arch).map_err(|e| e.to_string())?;
            let plan = plan_shards_weighted(&net, weights).map_err(|e| e.to_string())?;
            assert_plan_sound(&net, &plan)?;
            for l in split_layers(&plan) {
                for a in 0..plan.shards {
                    for b in 0..plan.shards {
                        // Units are apportioned largest-remainder, so a
                        // strictly heavier shard trails by at most one unit
                        // of weights+bias; a dominant weight gap must show.
                        if weights[a] >= 2.0 * weights[b]
                            && plan.owned_len(&net, a, l) < plan.owned_len(&net, b, l)
                        {
                            return Err(format!(
                                "layer {l}: shard {a} (w={}) owns less than shard {b} (w={})",
                                weights[a], weights[b]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Each seeded defect class is caught through the public API (the unit
/// tests pin exact defect fields; this proves the surface end-to-end).
#[test]
fn seeded_defects_are_detected_through_the_public_api() {
    let net = Network::from_name("small").unwrap();
    let fc = split_layers(&plan_shards(&net, 2))[0];
    let classes = |plan: &ShardPlan| -> Vec<&'static str> {
        verify_shards(&net, plan).defects.iter().map(|d| d.class()).collect()
    };

    // Straddled split point: shift the cut one param off the unit boundary.
    let mut plan = plan_shards(&net, 2);
    if let LayerAssignment::Split { pieces } = &mut plan.layers[fc] {
        pieces[0][0].end += 1;
        pieces[1][0].start += 1;
    }
    assert!(classes(&plan).contains(&"straddled-split-point"));

    // Gap: a shard forgets its bias block.
    let mut plan = plan_shards(&net, 2);
    if let LayerAssignment::Split { pieces } = &mut plan.layers[fc] {
        pieces[1].pop();
    }
    assert!(classes(&plan).contains(&"gap"));

    // Overlap within one shard: a sub-range listed twice.
    let mut plan = plan_shards(&net, 2);
    if let LayerAssignment::Split { pieces } = &mut plan.layers[fc] {
        let w = pieces[0][0].clone();
        pieces[0].push(w.start..w.start + 1);
    }
    assert!(classes(&plan).contains(&"overlap"));

    // Non-activation crossing: a truncated replica of a conv span.
    let mut plan = plan_shards(&net, 2);
    let conv = (0..net.dims.len())
        .find(|&l| {
            !net.dims[l].params.is_empty()
                && matches!(plan.layers[l], LayerAssignment::Replicated)
        })
        .unwrap();
    let span = net.dims[conv].params.clone();
    plan.layers[conv] = LayerAssignment::Copies(vec![span.clone(), span.start..span.end - 1]);
    assert!(classes(&plan).contains(&"non-activation-crossing"));

    // A defective plan is never scored.
    assert!(verify_shards(&net, &plan).score.is_none());
}
