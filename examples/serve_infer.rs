//! Serving example: spawn the batched-inference server on the **native**
//! engine (no PJRT artifacts needed) and serve concurrent prediction
//! requests with dynamic batching, reporting latency percentiles and
//! throughput.
//!
//! Run: `cargo run --release --example serve_infer -- [requests] [clients] [batch] [workers]`
//!
//! To serve through the AOT/PJRT path instead, build the artifacts
//! (`make artifacts`) and spawn with `serve::Engine::Pjrt` — the client
//! side of this example is engine-agnostic.

use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::serve::{Engine, Server, ServerConfig};
use chaos_phi::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);

    // Weights would normally come from a CHAOS training run
    // (`RunResult::final_params`); deterministic init keeps the example
    // self-contained.
    let net = Network::from_name("tiny")?;
    let params = net.init_params(1);
    let server = Server::spawn(
        Engine::Native { net, params, batch },
        ServerConfig {
            max_delay: std::time::Duration::from_millis(1),
            workers,
            ..Default::default()
        },
    )?;
    println!("server up (native batched engine, batch cap {batch}, {workers} workers)");

    let images = generate_synthetic(requests, 11, &SynthConfig::default()).resize(13);
    let sw = Stopwatch::start();
    let correct: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let handle = server.handle();
                let images = &images;
                s.spawn(move || {
                    let mut correct = 0;
                    let mut i = c;
                    while i < requests {
                        let probs = handle.predict(images.image(i)).expect("predict");
                        let pred = probs
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0;
                        correct += usize::from(pred == images.label(i));
                        i += clients;
                    }
                    correct
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let secs = sw.elapsed_secs();

    let m = server.handle().metrics.snapshot();
    println!("\n{requests} requests, {clients} concurrent clients");
    println!("throughput: {:.0} req/s  ({secs:.2}s total)", requests as f64 / secs);
    println!(
        "latency: p50 {:.0} µs   p99 {:.0} µs   max {:.0} µs",
        m.p50_us, m.p99_us, m.max_us
    );
    println!("batches: {} (mean fill {:.2} / {batch})", m.batches, m.mean_batch_fill);
    println!(
        "engine exec/batch: p50 {:.0} µs   p99 {:.0} µs   mean {:.0} µs",
        m.exec_p50_us, m.exec_p99_us, m.exec_mean_us
    );
    println!(
        "predictions from untrained weights: {}/{} correct (≈ chance, as expected)",
        correct, requests
    );
    Ok(())
}
