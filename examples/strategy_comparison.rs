//! Ablation: CHAOS against the strategies it was distilled from (§4.1) —
//! sequential SGD, averaged SGD (B), delayed round-robin (C), and pure
//! HogWild! (D) — same data, same seed, same epoch budget.
//!
//! Run: `cargo run --release --example strategy_comparison`

use chaos_phi::chaos::{train, Strategy};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::load_or_generate;
use chaos_phi::nn::Network;

fn main() -> anyhow::Result<()> {
    let net = Network::new(ArchSpec::small());
    let (train_set, test_set) = load_or_generate("data/mnist", 1_200, 500, 3);
    let base = TrainConfig {
        epochs: 3,
        threads: 4,
        eta0: 0.01,
        eta_decay: 0.9,
        seed: 11,
        validation_fraction: 0.2,
    };

    println!("| strategy | threads | final test err | train loss | publications | wall s |");
    println!("|---|---|---|---|---|---|");
    for strategy in [
        Strategy::Sequential,
        Strategy::Chaos,
        Strategy::Hogwild,
        Strategy::DelayedRoundRobin,
        Strategy::Averaged { sync_every: 32 },
    ] {
        let cfg = if matches!(strategy, Strategy::Sequential) {
            TrainConfig { threads: 1, ..base.clone() }
        } else {
            base.clone()
        };
        let r = train(&net, &train_set, &test_set, &cfg, strategy)?;
        let e = r.final_epoch();
        println!(
            "| {} | {} | {:.2}% | {:.1} | {} | {:.1} |",
            r.strategy,
            r.threads,
            e.test.error_rate() * 100.0,
            e.train.loss,
            r.publications,
            r.wall_secs
        );
    }
    println!("\nNotes: single-core host — wall times measure overhead, not speedup;");
    println!("accuracy columns show the paper's point: CHAOS ≈ sequential, while");
    println!("averaged SGD converges slower per epoch (§4.1 strategy B discussion).");
    Ok(())
}
