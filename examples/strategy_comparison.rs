//! Ablation: CHAOS against the strategies it was distilled from (§4.1) —
//! sequential SGD, averaged SGD (B), delayed round-robin (C), and pure
//! HogWild! (D) — same data, same seed, same epoch budget.
//!
//! The comparison iterates the *policy registry*, so a policy registered
//! through `chaos::policy::register` shows up here (and in the
//! `update_policies` bench) with no further changes.
//!
//! Run: `cargo run --release --example strategy_comparison`

use chaos_phi::chaos::{policy, Trainer};
use chaos_phi::config::ArchSpec;
use chaos_phi::data::load_or_generate;
use chaos_phi::nn::Network;

fn main() -> anyhow::Result<()> {
    let net = Network::new(ArchSpec::small());
    let (train_set, test_set) = load_or_generate("data/mnist", 1_200, 500, 3);

    println!("| policy | threads | final test err | train loss | publications | wall s |");
    println!("|---|---|---|---|---|---|");
    for name in policy::names() {
        // A registered factory may require a ':' argument; skip those.
        let Ok(update_policy) = policy::from_name(&name) else {
            println!("| {name} | - | (needs an argument — skipped) | - | - | - |");
            continue;
        };
        let threads = if update_policy.is_sequential() { 1 } else { 4 };
        let r = Trainer::new()
            .network(net.clone())
            .epochs(3)
            .threads(threads)
            .eta(0.01, 0.9)
            .seed(11)
            .validation_fraction(0.2)
            .policy_boxed(update_policy)
            .run(&train_set, &test_set)?;
        let e = r.final_epoch();
        println!(
            "| {} | {} | {:.2}% | {:.1} | {} | {:.1} |",
            r.strategy,
            r.threads,
            e.test.error_rate() * 100.0,
            e.train.loss,
            r.publications,
            r.wall_secs
        );
    }
    println!("\nNotes: single-core host — wall times measure overhead, not speedup;");
    println!("accuracy columns show the paper's point: CHAOS ≈ sequential, while");
    println!("averaged SGD converges slower per epoch (§4.1 strategy B discussion).");
    Ok(())
}
