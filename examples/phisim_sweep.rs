//! Simulator sweep: regenerates the Xeon Phi scaling experiments
//! (Figs 5–9, Tables 5–6) from the discrete-event machine model.
//!
//! Run: `cargo run --release --example phisim_sweep`

use chaos_phi::harness;
use chaos_phi::phisim::{speedup_table, PAPER_THREAD_COUNTS};

fn main() -> anyhow::Result<()> {
    println!("{}", harness::fig5()?.to_markdown());
    println!("{}", harness::fig6()?.to_markdown());
    for f in [7u8, 8, 9] {
        println!("{}", harness::fig_speedups(f)?.to_markdown());
    }
    println!("{}", harness::table5()?.to_markdown());
    println!("{}", harness::table6()?.to_markdown());

    // Headline summary (paper Result 3).
    let rows = speedup_table("large")?;
    let r244 = rows.iter().find(|r| r.threads == 244).unwrap();
    println!("### Headline (large net, 244 threads)\n");
    println!(
        "speedup vs Phi 1T: {:.1}x (paper 103x) | vs Xeon E5: {:.1}x (paper 14x) | vs Core i5: {:.1}x (paper 58x)",
        r244.vs_phi_1t, r244.vs_xeon_e5, r244.vs_core_i5
    );
    println!("thread counts simulated: {PAPER_THREAD_COUNTS:?}");
    Ok(())
}
