//! Quickstart: train a CNN with CHAOS in ~30 seconds, through the
//! [`Trainer`] builder — the public face of the coordinator.
//!
//! Builds the paper's "small" architecture, generates a synthetic MNIST
//! stand-in (or loads the real IDX files from `data/mnist/` if present),
//! trains sequentially and with CHAOS on 4 threads from the same seed, and
//! compares accuracy — the paper's core claim: asynchronous parallel
//! training matches sequential accuracy.
//!
//! The update scheme is a pluggable policy: swap `.policy(ChaosPolicy)`
//! for `.policy_name("averaged:64")?` (or any policy registered through
//! `chaos::policy::register`) and nothing else changes.
//!
//! Run: `cargo run --release --example quickstart`

use chaos_phi::chaos::{ChaosPolicy, SequentialPolicy, Trainer};
use chaos_phi::config::ArchSpec;
use chaos_phi::data::load_or_generate;
use chaos_phi::nn::Network;

fn main() -> anyhow::Result<()> {
    let net = Network::new(ArchSpec::small());
    println!(
        "small CNN: {} layers, {} parameters",
        net.dims.len(),
        net.total_params
    );

    let (train_set, test_set) = load_or_generate("data/mnist", 1_000, 400, 42);
    println!("data: {} train / {} test images\n", train_set.len(), test_set.len());

    // Shared hyper-parameters, stated once through the fluent builder.
    let trainer = || {
        Trainer::new()
            .network(net.clone())
            .epochs(3)
            .eta(0.01, 0.9)
            .seed(7)
            .validation_fraction(0.2)
    };

    println!("== sequential baseline ==");
    let seq = trainer().threads(1).policy(SequentialPolicy).run(&train_set, &test_set)?;
    for e in &seq.epochs {
        println!(
            "  epoch {}: train loss {:.1}, test error rate {:.2}%",
            e.epoch,
            e.train.loss,
            e.test.error_rate() * 100.0
        );
    }

    println!("\n== CHAOS, 4 threads (shared weights, per-layer delayed publication) ==");
    let par = trainer().threads(4).policy(ChaosPolicy).run(&train_set, &test_set)?;
    for e in &par.epochs {
        println!(
            "  epoch {}: train loss {:.1}, test error rate {:.2}%",
            e.epoch,
            e.train.loss,
            e.test.error_rate() * 100.0
        );
    }

    let s = seq.final_epoch().test.error_rate() * 100.0;
    let p = par.final_epoch().test.error_rate() * 100.0;
    println!("\nfinal test error: sequential {s:.2}% vs CHAOS {p:.2}%");
    println!(
        "CHAOS published {} per-layer updates through the shared store",
        par.publications
    );
    println!("\n(accuracy parity is the paper's Result 4; wall-clock speedup");
    println!(" needs >1 physical core — see `chaos simulate` for the Phi model)");
    Ok(())
}
