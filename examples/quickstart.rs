//! Quickstart: train a CNN with CHAOS in ~30 seconds, through the
//! [`Trainer`] builder — the public face of the coordinator.
//!
//! Four stops:
//!  1. the paper's "small" network, sequential baseline;
//!  2. the same network under CHAOS on 4 threads (accuracy parity — the
//!     paper's core claim);
//!  3. a custom architecture defined in JSON using the open layer
//!     vocabulary (strided/padded conv, ReLU, average pooling, dropout);
//!  4. a brand-new layer kind (`softsign`) registered from user code and
//!     trained end-to-end — no changes inside the crate.
//!
//! The update scheme is just as pluggable: swap `.policy(ChaosPolicy)` for
//! `.policy_name("averaged:64")?` (or any policy registered through
//! `chaos::policy::register`) and nothing else changes.
//!
//! Run: `cargo run --release --example quickstart`

use chaos_phi::chaos::{ChaosPolicy, SequentialPolicy, Trainer};
use chaos_phi::config::{ArchSpec, LayerSpec};
use chaos_phi::data::load_or_generate;
use chaos_phi::nn::layer::{self, LayerCtx, LayerKind};
use chaos_phi::nn::{Acts, LayerOp, Network, OpScratch, Shape};
use chaos_phi::util::Json;
use std::ops::Range;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let net = Network::new(ArchSpec::small());
    println!(
        "small CNN: {} layers, {} parameters",
        net.dims.len(),
        net.total_params
    );

    let (train_set, test_set) = load_or_generate("data/mnist", 1_000, 400, 42);
    println!("data: {} train / {} test images\n", train_set.len(), test_set.len());

    // Shared hyper-parameters, stated once through the fluent builder.
    let trainer = || {
        Trainer::new()
            .network(net.clone())
            .epochs(3)
            .eta(0.01, 0.9)
            .seed(7)
            .validation_fraction(0.2)
    };

    println!("== sequential baseline ==");
    let seq = trainer().threads(1).policy(SequentialPolicy).run(&train_set, &test_set)?;
    for e in &seq.epochs {
        println!(
            "  epoch {}: train loss {:.1}, test error rate {:.2}%",
            e.epoch,
            e.train.loss,
            e.test.error_rate() * 100.0
        );
    }

    println!("\n== CHAOS, 4 threads (shared weights, per-layer delayed publication) ==");
    let par = trainer().threads(4).policy(ChaosPolicy).run(&train_set, &test_set)?;
    for e in &par.epochs {
        println!(
            "  epoch {}: train loss {:.1}, test error rate {:.2}%",
            e.epoch,
            e.train.loss,
            e.test.error_rate() * 100.0
        );
    }

    let s = seq.final_epoch().test.error_rate() * 100.0;
    let p = par.final_epoch().test.error_rate() * 100.0;
    println!("\nfinal test error: sequential {s:.2}% vs CHAOS {p:.2}%");
    println!(
        "CHAOS published {} per-layer updates through the shared store",
        par.publications
    );

    // -----------------------------------------------------------------------
    // 3. A custom architecture from JSON: every layer object's key selects a
    //    registered kind, so the vocabulary below (strided+padded conv,
    //    ReLU, avgpool, dropout) needs no code.
    // -----------------------------------------------------------------------
    println!("\n== custom JSON architecture (new layer kinds) ==");
    let custom = ArchSpec::from_json(&Json::parse(
        r#"{
          "name": "json-custom", "epochs": 2, "layers": [
            {"input": 29},
            {"conv": {"maps": 6, "kernel": 5, "stride": 2, "pad": 2, "act": "relu"}},
            {"avgpool": 3},
            {"dropout": 0.25},
            {"fc": {"neurons": 32, "act": "relu"}},
            {"output": 10}
        ]}"#,
    )?)?;
    let run = Trainer::new()
        .arch(custom)
        .epochs(2)
        .threads(2)
        .eta(0.01, 0.9)
        .seed(7)
        .policy(ChaosPolicy)
        .run(&train_set, &test_set)?;
    println!(
        "  json-custom: test error {:.2}% after {} epochs",
        run.final_epoch().test.error_rate() * 100.0,
        run.epochs.len()
    );

    // -----------------------------------------------------------------------
    // 4. A brand-new layer kind from user code: softsign x/(1+|x|). One
    //    LayerKind (parse/validate/compile) + one LayerOp (kernels), one
    //    register call — then it is selectable from JSON like a built-in
    //    and trains under every update policy.
    // -----------------------------------------------------------------------
    println!("\n== runtime-registered custom layer kind: softsign ==");
    // Ignore the duplicate error if the example runs twice in one process.
    let _ = layer::register(Arc::new(SoftsignKind));
    let softy = ArchSpec::from_json(&Json::parse(
        r#"{
          "name": "softy", "epochs": 2, "layers": [
            {"input": 29},
            {"conv": {"maps": 5, "kernel": 4}},
            {"pool": 2},
            {"softsign": {}},
            {"fc": 30},
            {"output": 10}
        ]}"#,
    )?)?;
    let run = Trainer::new()
        .arch(softy)
        .epochs(2)
        .threads(2)
        .eta(0.01, 0.9)
        .seed(7)
        .policy(ChaosPolicy)
        .run(&train_set, &test_set)?;
    println!(
        "  softy: test error {:.2}% after {} epochs (kinds: {})",
        run.final_epoch().test.error_rate() * 100.0,
        run.epochs.len(),
        layer::names().join(", ")
    );

    println!("\n(accuracy parity is the paper's Result 4; wall-clock speedup");
    println!(" needs >1 physical core — see `chaos simulate` for the Phi model)");
    Ok(())
}

// ---------------------------------------------------------------------------
// The custom kind: an elementwise softsign activation layer.
// ---------------------------------------------------------------------------

struct SoftsignKind;

impl LayerKind for SoftsignKind {
    fn name(&self) -> &'static str {
        "softsign"
    }

    fn from_json(&self, _body: &Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::custom("softsign", vec![]))
    }

    fn to_json(&self, _spec: &LayerSpec) -> Json {
        Json::obj(vec![])
    }

    fn out_shape(
        &self,
        _spec: &LayerSpec,
        input: Shape,
        _ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        Ok(input) // elementwise: geometry passes through
    }

    fn compile(
        &self,
        _spec: &LayerSpec,
        dims: &chaos_phi::nn::LayerDims,
    ) -> anyhow::Result<Box<dyn LayerOp>> {
        Ok(Box::new(SoftsignOp {
            shape: Shape { maps: dims.out_maps, side: dims.out_side, flat: dims.flat },
        }))
    }
}

#[derive(Debug)]
struct SoftsignOp {
    shape: Shape,
}

impl LayerOp for SoftsignOp {
    fn kind(&self) -> &'static str {
        "softsign"
    }

    fn in_shape(&self) -> Shape {
        self.shape
    }

    fn out_shape(&self) -> Shape {
        self.shape
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        for (o, &x) in out.iter_mut().zip(input) {
            *o = x / (1.0 + x.abs());
        }
    }

    fn backward(
        &self,
        _: &[f32],
        acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return;
        }
        // dy/dx expressed through the output: (1 − |y|)².
        for ((di, &d), &y) in delta_in.iter_mut().zip(delta_out.iter()).zip(acts.output) {
            let g = 1.0 - y.abs();
            *di = d * g * g;
        }
    }
}
