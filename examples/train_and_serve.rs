//! Live-from-training serving: run CHAOS training and a multi-worker
//! inference server **concurrently against the same weights**, with no
//! checkpoint round-trip.
//!
//! The trainer exports its live `chaos::SharedParams` store
//! (`Trainer::export_store`); the server's `Engine::Shared` snapshots the
//! store per batch under the CHAOS per-layer lock contract — serving
//! threads are just extra readers, the same worker-heterogeneity argument
//! that lets training workers observe non-instant updates. Predictions
//! are validated mid-epoch (well-formed probability rows) and, once
//! training finishes, checked bit-identical against a frozen engine on
//! the run's final weights.
//!
//! Run: `cargo run --release --example train_and_serve -- [epochs] [threads] [workers]`

use chaos_phi::chaos::Trainer;
use chaos_phi::config::ArchSpec;
use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::runtime::NativeBatchEngine;
use chaos_phi::serve::{Server, ServerConfig};
use chaos_phi::util::Stopwatch;
use std::sync::mpsc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let threads: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let batch = 8usize;

    let train_set = generate_synthetic(400, 1, &SynthConfig::default()).resize(13);
    let test_set = generate_synthetic(100, 2, &SynthConfig::default()).resize(13);
    let queries = generate_synthetic(64, 3, &SynthConfig::default()).resize(13);

    // The trainer hands its live store out through this channel as soon as
    // the parallel engine comes up.
    let (store_tx, store_rx) = mpsc::channel();
    let trainer = Trainer::new()
        .arch(ArchSpec::tiny())
        .epochs(epochs)
        .threads(threads)
        .eta(0.05, 0.95)
        .seed(42)
        .export_store(store_tx);
    let sw = Stopwatch::start();
    let training = std::thread::spawn(move || trainer.run(&train_set, &test_set));

    let store = store_rx.recv().expect("parallel run exports its store");
    println!("training started ({threads} threads); live store received after {:.3}s", sw.elapsed_secs());

    // Serve straight out of the training store — no checkpoint, no copy of
    // record: every batch snapshots whatever the workers have published.
    let net = Network::from_name("tiny")?;
    let server = Server::spawn_shared(
        net.clone(),
        store.clone(),
        batch,
        ServerConfig {
            max_delay: Duration::from_micros(500),
            workers,
            ..Default::default()
        },
    )?;
    println!("server up: {workers} worker(s) serving live from the shared store");

    // Query continuously while training runs: rows must always be
    // well-formed probability distributions, whatever publication state
    // the snapshot catches.
    let handle = server.handle();
    let mut mid_epoch_queries = 0usize;
    while !training.is_finished() {
        for i in 0..queries.len() {
            let row = handle.predict(queries.image(i)).expect("live predict");
            assert_eq!(row.len(), 10);
            let sum: f32 = row.iter().sum();
            assert!(
                row.iter().all(|p| p.is_finite() && *p >= 0.0) && (sum - 1.0).abs() < 1e-3,
                "malformed probability row mid-training: sum {sum}"
            );
            mid_epoch_queries += 1;
        }
    }
    let run = training.join().expect("training thread")?;
    println!(
        "training done in {:.2}s: {} publications, final test error rate {:.1}%",
        sw.elapsed_secs(),
        run.publications,
        run.final_epoch().test.error_rate() * 100.0
    );
    println!("served {mid_epoch_queries} live queries mid-training");

    // Training has stopped publishing, so the live engine and a frozen
    // engine on the run's final weights must now agree bit-for-bit.
    let mut frozen = NativeBatchEngine::new(net, run.final_params.clone(), 1)?;
    for i in 0..queries.len() {
        let live = handle.predict(queries.image(i)).expect("post-training predict");
        let expect = frozen.run(queries.image(i), 1)?;
        assert_eq!(live, expect[0], "query {i}: live store diverged from final weights");
    }
    println!("post-training predictions bit-identical to the final checkpoint ✓");

    let m = server.handle().metrics.snapshot();
    println!(
        "serving metrics: {} requests, {} batches (mean fill {:.2}), p50 {:.0}µs p99 {:.0}µs, exec mean {:.0}µs",
        m.requests, m.batches, m.mean_batch_fill, m.p50_us, m.p99_us, m.exec_mean_us
    );
    Ok(())
}
