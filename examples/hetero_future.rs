//! The paper's future work, realized: heterogeneous CHAOS across host CPU
//! cores *and* the Xeon Phi co-processor (§6: "Future work will extend
//! CHAOS to enable the use of all cores of host CPUs and the
//! co-processor(s)"), on the simulated machine model.
//!
//! Run: `cargo run --release --example hetero_future`

use chaos_phi::phisim::{simulate_hetero, HeteroConfig};

fn main() -> anyhow::Result<()> {
    println!("## Heterogeneous CHAOS — host cores + Xeon Phi (phisim)\n");
    for arch in ["small", "medium", "large"] {
        println!("### {arch}\n");
        println!("| host cores | phi threads | epoch (s) | host share | vs phi-only |");
        println!("|---|---|---|---|---|");
        let phi_only = simulate_hetero(&HeteroConfig::paper(arch, 0, 244))?.train_epoch_secs;
        for (host, phi) in [(0usize, 244usize), (4, 244), (12, 244), (24, 244), (12, 0), (24, 0)] {
            if host + phi == 0 {
                continue;
            }
            let r = simulate_hetero(&HeteroConfig::paper(arch, host, phi))?;
            println!(
                "| {host} | {phi} | {:.1} | {:.1}% | {:.2}x |",
                r.train_epoch_secs,
                r.host_share() * 100.0,
                phi_only / r.train_epoch_secs
            );
        }
        println!();
    }
    println!("Dynamic image picking balances the asymmetric devices with no static split —");
    println!("the reason the scheme extends naturally, as the paper anticipated.");
    Ok(())
}
