//! End-to-end training driver (the repository's E2E validation run —
//! EXPERIMENTS.md §E2E records its output).
//!
//! Trains the paper's *medium* CNN (~76k parameters) with the CHAOS
//! coordinator on a real small workload: 4,000 synthetic-MNIST images (or
//! real MNIST when `data/mnist/` holds the IDX files), 6 epochs — several
//! hundred thousand per-sample SGD steps across 4 asynchronous workers —
//! and logs the full loss/error curve, proving all layers compose:
//! data → nn kernels → shared-weight store → CHAOS workers → reporter.
//!
//! Run: `cargo run --release --example train_mnist -- [train_n] [epochs] [threads]`

use chaos_phi::chaos::{observer_fn, ChaosPolicy, TrainControl, Trainer};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::load_or_generate;
use chaos_phi::nn::Network;
use chaos_phi::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let train_n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4_000);
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let threads: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let net = Network::new(ArchSpec::medium());
    println!(
        "medium CNN: {} parameters; CHAOS with {threads} threads; {epochs} epochs",
        net.total_params
    );
    let (train_set, test_set) = load_or_generate("data/mnist", train_n, train_n / 4, 1234);
    println!("data: {} train / {} test images", train_set.len(), test_set.len());

    let cfg = TrainConfig {
        epochs,
        threads,
        eta0: 0.005,
        eta_decay: 0.9,
        seed: 99,
        validation_fraction: 0.2,
        eval_batch: 32,
        ..TrainConfig::default()
    };
    let sw = Stopwatch::start();
    // Live progress through the observer API (fires as each epoch lands).
    let run = Trainer::new()
        .network(net)
        .config(cfg)
        .policy(ChaosPolicy)
        .observer(observer_fn(|e, _run| {
            eprintln!(
                "[live] epoch {} done: train loss {:.1}, test err {:.2}%",
                e.epoch,
                e.train.loss,
                e.test.error_rate() * 100.0
            );
            TrainControl::Continue
        }))
        .run(&train_set, &test_set)?;

    println!("\nepoch |   eta    | train loss | train err% | val err% | test err% | secs");
    println!("------|----------|------------|------------|----------|-----------|-----");
    for e in &run.epochs {
        println!(
            "{:>5} | {:.6} | {:>10.1} | {:>9.2}% | {:>7.2}% | {:>8.2}% | {:>5.1}",
            e.epoch,
            e.eta,
            e.train.loss,
            100.0 * e.train.errors as f64 / e.train.images.max(1) as f64,
            e.validation.error_rate() * 100.0,
            e.test.error_rate() * 100.0,
            e.total_secs
        );
    }

    let first = &run.epochs[0];
    let last = run.final_epoch();
    println!("\nwall time: {:.1}s", sw.elapsed_secs());
    println!(
        "loss: {:.1} -> {:.1} ({}x reduction); test error {:.2}% -> {:.2}%",
        first.train.loss,
        last.train.loss,
        (first.train.loss / last.train.loss).round(),
        first.test.error_rate() * 100.0,
        last.test.error_rate() * 100.0
    );
    println!("shared-store publications: {}", run.publications);

    // Per-layer time accounting (the paper's Table-1 shape: conv dominates).
    use chaos_phi::util::timer::LayerClass as LC;
    let t = &run.layer_times;
    let conv = t.get_secs(LC::ConvForward) + t.get_secs(LC::ConvBackward);
    println!(
        "layer times: conv {:.1}s ({:.1}% of layer time), pool {:.1}s, fc+out {:.1}s",
        conv,
        100.0 * conv / t.total_secs(),
        t.get_secs(LC::PoolForward) + t.get_secs(LC::PoolBackward),
        t.get_secs(LC::FcForward)
            + t.get_secs(LC::FcBackward)
            + t.get_secs(LC::OutputForward)
            + t.get_secs(LC::OutputBackward),
    );

    run.save("train_mnist_run.json")?;
    println!("run record written to train_mnist_run.json");
    anyhow::ensure!(
        last.train.loss < first.train.loss * 0.6,
        "E2E failed: loss did not fall substantially"
    );
    println!("E2E OK");
    Ok(())
}
