//! Performance-model explorer: regenerates the paper's prediction
//! experiments (Tables 8/9, Figs 11–13) and prints per-term breakdowns.
//!
//! Run: `cargo run --release --example perf_model`

use chaos_phi::harness;
use chaos_phi::perfmodel::{PerfModel, Scenario};

fn main() -> anyhow::Result<()> {
    println!("{}", harness::table8()?.to_markdown());
    println!("{}", harness::table9()?.to_markdown());
    for arch in ["small", "medium", "large"] {
        println!("{}", harness::fig_pred_vs_measured(arch)?.to_markdown());
    }

    // Term-level view at the paper's flagship configuration.
    println!("### Breakdown at 244 threads (seconds)\n");
    println!("| arch | sequential | training | validation | testing | memory | total |");
    println!("|---|---|---|---|---|---|---|");
    for arch in ["small", "medium", "large"] {
        let m = PerfModel::for_arch(arch)?;
        let b = m.predict_breakdown(&Scenario::paper_default(arch, 244));
        println!(
            "| {arch} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} |",
            b.sequential, b.training, b.validation, b.testing, b.memory, b.total()
        );
    }
    Ok(())
}
