//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io registry), so this
//! vendored crate implements exactly the subset chaos-phi uses: the
//! [`Error`] type with source-chain `{:#}` formatting, the [`Result`]
//! alias, the `anyhow!` / `bail!` / `ensure!` macros, and the [`Context`]
//! extension trait. Code written against it compiles unchanged against
//! real `anyhow`. One deliberate simplification: [`Error::context`]
//! flattens the wrapped error into the rendered message (the real crate
//! keeps the source chain walkable behind the context layer), so
//! `chain()`/downcast-based inspection stops at a contextualized error.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Inner {
    /// A free-standing message (`anyhow!("...")`).
    Msg(String),
    /// A wrapped concrete error (`?` conversion).
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
}

/// A dynamic error with an optional source chain.
///
/// Like the real `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket `From` impl below
/// coherent.
pub struct Error {
    inner: Inner,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Inner::Msg(message.to_string()) }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Inner::Boxed(Box::new(error)) }
    }

    /// Prefix this error with higher-level context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error::msg(format!("{context}: {self:#}"))
    }

    /// The chain of sources below the top-level error, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        let next = match &self.inner {
            Inner::Msg(_) => None,
            Inner::Boxed(e) => e.source(),
        };
        Chain { next }
    }
}

/// Iterator over an [`Error`]'s source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.inner {
            Inner::Msg(m) => f.write_str(m)?,
            Inner::Boxed(e) => write!(f, "{e}")?,
        }
        // `{:#}` appends the full cause chain, `: cause: cause: ...`.
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        let mut first = true;
        for cause in self.chain() {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_concrete_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"), "{e}");
    }

    #[test]
    fn macros_build_messages() {
        let x = 7;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        let e2 = anyhow!("bad value {}", x + 1);
        assert_eq!(e2.to_string(), "bad value 8");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable for true? no: always bails at {}", 42);
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert!(f(true).unwrap_err().to_string().contains("42"));
    }

    #[test]
    fn alternate_format_appends_sources() {
        let e = Error::new(io_err()).context("loading config");
        let plain = format!("{e}");
        assert!(plain.starts_with("loading config"), "{plain}");
        assert!(plain.contains("missing file"), "{plain}");
    }

    #[test]
    fn context_trait_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| "during load").unwrap_err();
        assert!(e.to_string().starts_with("during load"), "{e}");
    }
}
