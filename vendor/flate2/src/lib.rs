//! Minimal, dependency-free stand-in for the `flate2` crate.
//!
//! The build environment is fully offline (no crates.io registry), so this
//! vendored crate implements the subset chaos-phi uses:
//!
//! * [`Crc`] — the CRC32 (IEEE, reflected) checksum used by the checkpoint
//!   format;
//! * [`write::GzEncoder`] — a gzip writer. It emits *stored* (uncompressed)
//!   DEFLATE blocks: byte-identical data, valid RFC 1951/1952 streams, no
//!   compression. Every standard gzip reader accepts the output;
//! * [`read::GzDecoder`] — a gzip reader with a complete DEFLATE
//!   decompressor (stored, fixed-Huffman and dynamic-Huffman blocks; the
//!   decoder follows zlib's reference `puff.c` structure), so real
//!   gzip-compressed files (e.g. the distributed MNIST IDX archives) load
//!   correctly.

/// Compression level knob. Accepted for API compatibility; the encoder
/// always writes stored blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(pub u32);

impl Compression {
    pub fn none() -> Compression {
        Compression(0)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

// ---------------------------------------------------------------------------
// CRC32
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// Running CRC32 (IEEE polynomial, reflected — the gzip/zlib checksum).
#[derive(Debug, Clone, Default)]
pub struct Crc {
    state: u32,
    amount: u32,
}

impl Crc {
    pub fn new() -> Crc {
        Crc::default()
    }

    /// Feed bytes into the checksum.
    pub fn update(&mut self, data: &[u8]) {
        let mut c = !self.state;
        for &b in data {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = !c;
        self.amount = self.amount.wrapping_add(data.len() as u32);
    }

    /// The checksum of everything fed so far.
    pub fn sum(&self) -> u32 {
        self.state
    }

    /// Number of bytes fed so far (mod 2³²).
    pub fn amount(&self) -> u32 {
        self.amount
    }

    pub fn reset(&mut self) {
        self.state = 0;
        self.amount = 0;
    }
}

fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc::new();
    c.update(data);
    c.sum()
}

// ---------------------------------------------------------------------------
// Gzip writer (stored DEFLATE blocks)
// ---------------------------------------------------------------------------

pub mod write {
    use super::{crc32, Compression};
    use std::io::{self, Write};

    /// Gzip encoder over any [`Write`] sink. Input is buffered and written
    /// as a single gzip member on [`GzEncoder::finish`].
    pub struct GzEncoder<W: Write> {
        inner: Option<W>,
        buf: Vec<u8>,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(writer: W, _level: Compression) -> GzEncoder<W> {
            GzEncoder { inner: Some(writer), buf: Vec::new() }
        }

        /// Write the complete gzip stream and return the underlying writer.
        pub fn finish(mut self) -> io::Result<W> {
            let mut w = self.inner.take().expect("encoder already finished");
            // RFC 1952 header: magic, CM=deflate, FLG=0, MTIME=0, XFL=0,
            // OS=255 (unknown).
            w.write_all(&[0x1f, 0x8b, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xff])?;
            // RFC 1951 stored blocks: 3-bit header (BFINAL, BTYPE=00) padded
            // to the byte boundary, then LEN / NLEN / raw bytes. The writer
            // is byte-aligned at every block start, so the header is one
            // whole byte.
            let mut chunks = self.buf.chunks(0xFFFF).peekable();
            if chunks.peek().is_none() {
                // Empty input still needs one final (empty) stored block.
                w.write_all(&[0x01, 0x00, 0x00, 0xFF, 0xFF])?;
            } else {
                while let Some(chunk) = chunks.next() {
                    let last = chunks.peek().is_none();
                    let len = chunk.len() as u16;
                    w.write_all(&[u8::from(last)])?;
                    w.write_all(&len.to_le_bytes())?;
                    w.write_all(&(!len).to_le_bytes())?;
                    w.write_all(chunk)?;
                }
            }
            // RFC 1952 trailer: CRC32 and ISIZE of the uncompressed data.
            w.write_all(&crc32(&self.buf).to_le_bytes())?;
            w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            w.flush()?;
            Ok(w)
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Gzip reader
// ---------------------------------------------------------------------------

pub mod read {
    use std::io::{self, Read};

    /// Gzip decoder over any [`Read`] source. The whole member is read and
    /// inflated on first use; subsequent reads serve from the buffer.
    pub struct GzDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(reader: R) -> GzDecoder<R> {
            GzDecoder { inner: Some(reader), out: Vec::new(), pos: 0 }
        }

        fn decode_all(&mut self, mut reader: R) -> io::Result<()> {
            let mut raw = Vec::new();
            reader.read_to_end(&mut raw)?;
            self.out = super::gunzip(&raw).map_err(|e| {
                io::Error::new(io::ErrorKind::InvalidData, e.to_string())
            })?;
            Ok(())
        }
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let Some(reader) = self.inner.take() {
                self.decode_all(reader)?;
            }
            let remaining = &self.out[self.pos..];
            let n = remaining.len().min(buf.len());
            buf[..n].copy_from_slice(&remaining[..n]);
            self.pos += n;
            Ok(n)
        }
    }
}

/// Decode one gzip member (header + DEFLATE stream + trailer).
fn gunzip(raw: &[u8]) -> Result<Vec<u8>, InflateError> {
    let body = parse_gzip_header(raw)?;
    let (out, consumed) = inflate::inflate(&raw[body..])?;
    // Trailer: CRC32 then ISIZE, little-endian, byte-aligned after the
    // DEFLATE stream.
    let trailer = body + consumed;
    if raw.len() < trailer + 8 {
        return Err(InflateError::new("truncated gzip trailer"));
    }
    let le32 = |off: usize| {
        u32::from_le_bytes([raw[off], raw[off + 1], raw[off + 2], raw[off + 3]])
    };
    let want_crc = le32(trailer);
    let want_len = le32(trailer + 4);
    if crc32(&out) != want_crc {
        return Err(InflateError::new("gzip crc mismatch"));
    }
    if out.len() as u32 != want_len {
        return Err(InflateError::new("gzip length mismatch"));
    }
    Ok(out)
}

/// Validate the RFC 1952 header; returns the offset of the DEFLATE stream.
fn parse_gzip_header(raw: &[u8]) -> Result<usize, InflateError> {
    if raw.len() < 10 {
        return Err(InflateError::new("truncated gzip header"));
    }
    if raw[0] != 0x1f || raw[1] != 0x8b {
        return Err(InflateError::new("not a gzip stream (bad magic)"));
    }
    if raw[2] != 8 {
        return Err(InflateError::new("unsupported gzip compression method"));
    }
    let flg = raw[3];
    let mut pos = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA: 2-byte little-endian length, then that many bytes.
        if raw.len() < pos + 2 {
            return Err(InflateError::new("truncated FEXTRA field"));
        }
        let xlen = u16::from_le_bytes([raw[pos], raw[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    for flag in [0x08u8, 0x10] {
        // FNAME / FCOMMENT: zero-terminated strings.
        if flg & flag != 0 {
            let end = raw[pos.min(raw.len())..]
                .iter()
                .position(|&b| b == 0)
                .ok_or_else(|| InflateError::new("unterminated gzip header string"))?;
            pos += end + 1;
        }
    }
    if flg & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    if pos > raw.len() {
        return Err(InflateError::new("truncated gzip header fields"));
    }
    Ok(pos)
}

/// DEFLATE decoding error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InflateError {
    msg: &'static str,
}

impl InflateError {
    fn new(msg: &'static str) -> InflateError {
        InflateError { msg }
    }
}

impl std::fmt::Display for InflateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.msg)
    }
}

impl std::error::Error for InflateError {}

mod inflate {
    //! RFC 1951 DEFLATE decoder, structured after zlib's reference
    //! implementation `contrib/puff/puff.c` (bit-at-a-time canonical
    //! Huffman decoding — slow but simple and exact).

    use super::InflateError;

    const MAX_BITS: usize = 15;
    const MAX_LIT_CODES: usize = 286;
    const MAX_DIST_CODES: usize = 30;

    fn err(msg: &'static str) -> InflateError {
        InflateError::new(msg)
    }

    struct Bits<'a> {
        data: &'a [u8],
        pos: usize,
        bitbuf: u32,
        bitcnt: u32,
    }

    impl<'a> Bits<'a> {
        fn new(data: &'a [u8]) -> Bits<'a> {
            Bits { data, pos: 0, bitbuf: 0, bitcnt: 0 }
        }

        /// Take `need` bits, LSB-first (need ≤ 13 in DEFLATE).
        fn bits(&mut self, need: u32) -> Result<u32, InflateError> {
            let mut val = self.bitbuf;
            while self.bitcnt < need {
                let byte = *self
                    .data
                    .get(self.pos)
                    .ok_or_else(|| err("unexpected end of deflate stream"))?
                    as u32;
                self.pos += 1;
                val |= byte << self.bitcnt;
                self.bitcnt += 8;
            }
            self.bitbuf = val >> need;
            self.bitcnt -= need;
            Ok(val & ((1u32 << need) - 1))
        }
    }

    struct Huffman {
        /// count[len] = number of codes of bit length `len`.
        count: [u16; MAX_BITS + 1],
        /// Symbols in canonical order.
        symbol: Vec<u16>,
    }

    impl Huffman {
        /// Build from per-symbol code lengths. Returns (table, left) where
        /// `left` > 0 marks an incomplete code and < 0 an over-subscribed
        /// one (matching puff's `construct`).
        fn construct(lengths: &[u16]) -> (Huffman, i32) {
            let mut count = [0u16; MAX_BITS + 1];
            for &l in lengths {
                count[l as usize] += 1;
            }
            let mut left: i32 = 1;
            if count[0] as usize != lengths.len() {
                for c in count.iter().skip(1) {
                    left <<= 1;
                    left -= *c as i32;
                    if left < 0 {
                        return (Huffman { count, symbol: Vec::new() }, left);
                    }
                }
            } else {
                left = 0; // no codes at all: treat as complete-and-empty
            }
            let mut offs = [0u16; MAX_BITS + 1];
            for len in 1..MAX_BITS {
                offs[len + 1] = offs[len] + count[len];
            }
            let mut symbol = vec![0u16; lengths.len()];
            for (sym, &l) in lengths.iter().enumerate() {
                if l != 0 {
                    symbol[offs[l as usize] as usize] = sym as u16;
                    offs[l as usize] += 1;
                }
            }
            (Huffman { count, symbol }, left)
        }
    }

    /// Decode one symbol (puff's `decode`).
    fn decode(br: &mut Bits<'_>, h: &Huffman) -> Result<u16, InflateError> {
        let mut code: i32 = 0;
        let mut first: i32 = 0;
        let mut index: i32 = 0;
        for len in 1..=MAX_BITS {
            code |= br.bits(1)? as i32;
            let count = h.count[len] as i32;
            if code - count < first {
                return Ok(h.symbol[(index + (code - first)) as usize]);
            }
            index += count;
            first += count;
            first <<= 1;
            code <<= 1;
        }
        Err(err("invalid huffman code"))
    }

    const LEN_BASE: [u16; 29] = [
        3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99,
        115, 131, 163, 195, 227, 258,
    ];
    const LEN_EXTRA: [u32; 29] = [
        0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
    ];
    const DIST_BASE: [u16; 30] = [
        1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025,
        1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
    ];
    const DIST_EXTRA: [u32; 30] = [
        0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12,
        12, 13, 13,
    ];

    /// Decode literal/length + distance codes until end-of-block.
    fn codes(
        br: &mut Bits<'_>,
        out: &mut Vec<u8>,
        lencode: &Huffman,
        distcode: &Huffman,
    ) -> Result<(), InflateError> {
        loop {
            let sym = decode(br, lencode)?;
            if sym < 256 {
                out.push(sym as u8);
            } else if sym == 256 {
                return Ok(());
            } else {
                let sym = (sym - 257) as usize;
                if sym >= 29 {
                    return Err(err("invalid length symbol"));
                }
                let len = LEN_BASE[sym] as usize + br.bits(LEN_EXTRA[sym])? as usize;
                let dsym = decode(br, distcode)? as usize;
                if dsym >= 30 {
                    return Err(err("invalid distance symbol"));
                }
                let dist = DIST_BASE[dsym] as usize + br.bits(DIST_EXTRA[dsym])? as usize;
                if dist > out.len() {
                    return Err(err("distance too far back"));
                }
                let start = out.len() - dist;
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
        }
    }

    fn stored(br: &mut Bits<'_>, out: &mut Vec<u8>) -> Result<(), InflateError> {
        // Discard bits to the byte boundary.
        br.bitbuf = 0;
        br.bitcnt = 0;
        if br.data.len() < br.pos + 4 {
            return Err(err("truncated stored block header"));
        }
        let len = u16::from_le_bytes([br.data[br.pos], br.data[br.pos + 1]]) as usize;
        let nlen = u16::from_le_bytes([br.data[br.pos + 2], br.data[br.pos + 3]]);
        if nlen != !(len as u16) {
            return Err(err("stored block length check failed"));
        }
        br.pos += 4;
        if br.data.len() < br.pos + len {
            return Err(err("truncated stored block data"));
        }
        out.extend_from_slice(&br.data[br.pos..br.pos + len]);
        br.pos += len;
        Ok(())
    }

    fn fixed_tables() -> (Huffman, Huffman) {
        let mut lengths = [0u16; 288];
        for (sym, l) in lengths.iter_mut().enumerate() {
            *l = match sym {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        let (lencode, _) = Huffman::construct(&lengths);
        let dist_lengths = [5u16; MAX_DIST_CODES];
        let (distcode, _) = Huffman::construct(&dist_lengths);
        (lencode, distcode)
    }

    fn dynamic_tables(br: &mut Bits<'_>) -> Result<(Huffman, Huffman), InflateError> {
        const ORDER: [usize; 19] =
            [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];
        let hlit = br.bits(5)? as usize + 257;
        let hdist = br.bits(5)? as usize + 1;
        let hclen = br.bits(4)? as usize + 4;
        if hlit > MAX_LIT_CODES || hdist > MAX_DIST_CODES {
            return Err(err("too many dynamic codes"));
        }

        let mut cl_lengths = [0u16; 19];
        for &idx in ORDER.iter().take(hclen) {
            cl_lengths[idx] = br.bits(3)? as u16;
        }
        let (clcode, left) = Huffman::construct(&cl_lengths);
        if left != 0 {
            return Err(err("bad code-length huffman code"));
        }

        let mut lengths = vec![0u16; hlit + hdist];
        let mut index = 0usize;
        while index < lengths.len() {
            let sym = decode(br, &clcode)?;
            match sym {
                0..=15 => {
                    lengths[index] = sym;
                    index += 1;
                }
                16 => {
                    if index == 0 {
                        return Err(err("repeat with no previous length"));
                    }
                    let prev = lengths[index - 1];
                    let rep = 3 + br.bits(2)? as usize;
                    if index + rep > lengths.len() {
                        return Err(err("repeat past end of lengths"));
                    }
                    for _ in 0..rep {
                        lengths[index] = prev;
                        index += 1;
                    }
                }
                17 | 18 => {
                    let rep = if sym == 17 {
                        3 + br.bits(3)? as usize
                    } else {
                        11 + br.bits(7)? as usize
                    };
                    if index + rep > lengths.len() {
                        return Err(err("repeat past end of lengths"));
                    }
                    index += rep; // already zero
                }
                _ => return Err(err("invalid code-length symbol")),
            }
        }
        if lengths[256] == 0 {
            return Err(err("missing end-of-block code"));
        }

        let (lencode, left) = Huffman::construct(&lengths[..hlit]);
        if left < 0 || (left > 0 && hlit != (lencode.count[0] + lencode.count[1]) as usize) {
            return Err(err("bad literal/length huffman code"));
        }
        let (distcode, left) = Huffman::construct(&lengths[hlit..]);
        if left < 0 || (left > 0 && hdist != (distcode.count[0] + distcode.count[1]) as usize) {
            return Err(err("bad distance huffman code"));
        }
        Ok((lencode, distcode))
    }

    /// Inflate a DEFLATE stream; returns (output, bytes consumed). The
    /// stream's trailing partial byte counts as consumed.
    pub fn inflate(data: &[u8]) -> Result<(Vec<u8>, usize), InflateError> {
        let mut br = Bits::new(data);
        let mut out = Vec::new();
        loop {
            let last = br.bits(1)?;
            match br.bits(2)? {
                0 => stored(&mut br, &mut out)?,
                1 => {
                    let (lencode, distcode) = fixed_tables();
                    codes(&mut br, &mut out, &lencode, &distcode)?;
                }
                2 => {
                    let (lencode, distcode) = dynamic_tables(&mut br)?;
                    codes(&mut br, &mut out, &lencode, &distcode)?;
                }
                _ => return Err(err("invalid block type")),
            }
            if last == 1 {
                break;
            }
        }
        Ok((out, br.pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn crc32_known_vector() {
        // The canonical CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        let mut c = Crc::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.sum(), 0xCBF4_3926);
        assert_eq!(c.amount(), 9);
    }

    fn gz_roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let compressed = enc.finish().unwrap();
        let mut out = Vec::new();
        read::GzDecoder::new(&compressed[..]).read_to_end(&mut out).unwrap();
        out
    }

    #[test]
    fn roundtrip_small() {
        let data = b"hello, stored gzip world";
        assert_eq!(gz_roundtrip(data), data);
    }

    #[test]
    fn roundtrip_empty() {
        assert_eq!(gz_roundtrip(b""), b"");
    }

    #[test]
    fn roundtrip_multi_block() {
        // > 65535 bytes forces multiple stored blocks.
        let data: Vec<u8> = (0..200_000u32).map(|i| (i * 7 + i / 251) as u8).collect();
        assert_eq!(gz_roundtrip(&data), data);
    }

    #[test]
    fn header_with_fname_accepted() {
        // Hand-built member: FLG=FNAME, name "x\0", empty final stored block.
        let mut raw = vec![0x1f, 0x8b, 0x08, 0x08, 0, 0, 0, 0, 0, 0xff];
        raw.extend_from_slice(b"x\0");
        raw.extend_from_slice(&[0x01, 0x00, 0x00, 0xFF, 0xFF]);
        raw.extend_from_slice(&crc32(b"").to_le_bytes());
        raw.extend_from_slice(&0u32.to_le_bytes());
        let mut out = Vec::new();
        read::GzDecoder::new(&raw[..]).read_to_end(&mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn corrupt_crc_rejected() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(b"payload").unwrap();
        let mut compressed = enc.finish().unwrap();
        let n = compressed.len();
        compressed[n - 5] ^= 0xFF; // flip a CRC byte
        let mut out = Vec::new();
        let e = read::GzDecoder::new(&compressed[..]).read_to_end(&mut out).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut out = Vec::new();
        assert!(read::GzDecoder::new(&b"not gzip at all"[..]).read_to_end(&mut out).is_err());
    }

    #[test]
    fn fixed_huffman_block_decodes() {
        // Hand-assembled fixed-Huffman block containing the single literal
        // 'a' (97). Fixed code for 97: 8 bits, value 0x30 + 97 = 0x91,
        // emitted MSB-first; end-of-block (256): 7 bits, 0000000.
        // Bit stream (LSB-first packing): BFINAL=1, BTYPE=01, then codes.
        let mut bits: Vec<u8> = Vec::new(); // individual bits, in write order
        bits.push(1); // BFINAL
        bits.extend_from_slice(&[1, 0]); // BTYPE=01, LSB first
        for i in (0..8).rev() {
            bits.push((0x91u8 >> i) & 1); // literal 'a', MSB first
        }
        bits.extend_from_slice(&[0, 0, 0, 0, 0, 0, 0]); // EOB, 7 zero bits
        let mut packed = Vec::new();
        for chunk in bits.chunks(8) {
            let mut byte = 0u8;
            for (i, b) in chunk.iter().enumerate() {
                byte |= b << i;
            }
            packed.push(byte);
        }
        let (out, _) = inflate::inflate(&packed).unwrap();
        assert_eq!(out, b"a");
    }
}
