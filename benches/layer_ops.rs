//! Bench: per-op forward/backward microbenchmarks for every registered
//! layer kind at paper-architecture shapes.
//!
//! Each compiled op is driven directly (the orchestrator stripped away), so
//! the numbers are the per-layer-class costs the performance model's
//! parameters (perfmodel::LayerCosts) are meant to predict — compare the
//! reported ns/op against the per-layer MAC-style operation counts in the
//! notes. The "zoo" architecture exercises the kinds absent from the paper
//! networks (padded/strided conv, ReLU, average pooling, dropout).

use chaos_phi::bench::{Bench, Report};
use chaos_phi::config::{Act, ArchSpec, LayerSpec};
use chaos_phi::nn::{Acts, Network, OpScratch};
use chaos_phi::perfmodel::LayerCosts;
use chaos_phi::util::Pcg32;

fn zoo_arch() -> ArchSpec {
    ArchSpec {
        name: "zoo".into(),
        layers: vec![
            LayerSpec::Input { side: 29 },
            LayerSpec::conv_ex(8, 5, 2, 2, Act::Relu), // 15x15
            LayerSpec::AvgPool { kernel: 3 },          // 5x5
            LayerSpec::Dropout { rate: 0.25 },
            LayerSpec::fc_act(64, Act::Relu),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    }
}

fn bench_net(report: &mut Report, net: &Network, iters: usize) {
    let params = net.init_params(1);
    let mut scratch = net.scratch();
    scratch.train_mode = true;
    let mut rng = Pcg32::seeded(7);
    let side = net.arch.input_side();
    let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();
    // Populate every layer's activations once so each op sees a realistic
    // input distribution.
    net.forward(&params.as_slice(), &img, &mut scratch, None);
    let acts: Vec<Vec<f32>> = scratch.acts.clone();
    let costs = LayerCosts::of(&net.arch);

    for l in 1..net.dims.len() {
        let d = &net.dims[l];
        let op = &net.ops[l];
        let label = format!("{}/L{l}:{}({}→{})", net.arch.name, op.kind(), d.in_len(), d.out_len());
        let layer_params = params[d.params.clone()].to_vec();
        let input = acts[l - 1].clone();
        let output = acts[l].clone();

        let mut out = vec![0.0f32; d.out_len()];
        let mut aux = vec![0u32; op.aux_len()];
        let mut op_rng = Pcg32::seeded(3);
        report.add(Bench::new(format!("{label}/fwd")).warmup(2).iters(iters).run(|| {
            op.forward(
                &layer_params,
                &input,
                &mut out,
                &mut OpScratch { aux: &mut aux, rng: &mut op_rng, train: true },
            );
            out[0]
        }));

        let mut delta_out_proto = vec![0.0f32; d.out_len()];
        for (v, seed) in delta_out_proto.iter_mut().zip(0..) {
            *v = ((seed % 13) as f32 - 6.0) * 1e-3;
        }
        let mut delta_out = delta_out_proto.clone();
        let mut delta_in = vec![0.0f32; d.in_len()];
        let mut grads = vec![0.0f32; d.param_count()];
        report.add(Bench::new(format!("{label}/bwd")).warmup(2).iters(iters).run(|| {
            delta_out.copy_from_slice(&delta_out_proto);
            grads.fill(0.0);
            op.backward(
                &layer_params,
                Acts { input: &input, output: &output },
                &mut delta_out,
                &mut delta_in,
                &mut grads,
                &mut OpScratch { aux: &mut aux, rng: &mut op_rng, train: true },
            );
            delta_in[0]
        }));

        let (fwd_ops, bwd_ops) = costs.per_layer[l];
        report.note(format!(
            "{label}: perfmodel cost weights fwd {fwd_ops:.0} / bwd {bwd_ops:.0} ops"
        ));
    }
}

fn main() {
    let mut report =
        Report::new("layer_ops — per-kind forward/backward at paper-architecture shapes");
    println!("registered layer kinds: {}", chaos_phi::nn::layer::names().join(", "));
    for name in ["small", "medium", "large"] {
        let net = Network::from_name(name).unwrap();
        let iters = if name == "large" { 6 } else { 20 };
        bench_net(&mut report, &net, iters);
    }
    bench_net(&mut report, &Network::new(zoo_arch()), 20);
    report.print();
}
