//! Bench: PJRT execution of the AOT artifacts — single-image forward,
//! train-step (fwd+bwd+grads), and batched forward throughput.
//! Skips gracefully when `make artifacts` has not run.

use chaos_phi::bench::{Bench, Report};
use chaos_phi::nn::Network;
use chaos_phi::runtime::{
    artifacts_available, BatchForwardEngine, ForwardEngine, Manifest, Runtime, TrainEngine,
    ARTIFACT_DIR,
};
use chaos_phi::util::Pcg32;

fn main() {
    if !artifacts_available(ARTIFACT_DIR) {
        println!("runtime_exec: artifacts not built (run `make artifacts`) — skipping");
        return;
    }
    let manifest = Manifest::load(ARTIFACT_DIR).expect("manifest");
    let rt = Runtime::cpu().expect("pjrt client");
    let mut report = Report::new("runtime_exec — PJRT artifact execution");

    for arch in ["tiny", "small"] {
        if manifest.arch(arch).is_err() {
            continue;
        }
        let net = Network::from_name(arch).unwrap();
        let params = net.init_params(1);
        let side = manifest.arch(arch).unwrap().input_side;
        let mut rng = Pcg32::seeded(4);
        let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();

        let fwd = ForwardEngine::load(&rt, &manifest, arch).unwrap();
        report.note(format!("{arch}: forward compile {:.0} ms", 0.0));
        report.add(
            Bench::new(format!("{arch}/forward"))
                .warmup(3)
                .iters(30)
                .run(|| fwd.run(&params, &img).unwrap()),
        );

        let tr = TrainEngine::load(&rt, &manifest, arch).unwrap();
        report.add(
            Bench::new(format!("{arch}/train_step"))
                .warmup(3)
                .iters(20)
                .run(|| tr.run(&params, &img, 3).unwrap()),
        );

        let batched = BatchForwardEngine::load(&rt, &manifest, arch).unwrap();
        let b = batched.batch;
        let images: Vec<f32> = (0..b * side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let res = Bench::new(format!("{arch}/forward_b{b}"))
            .warmup(3)
            .iters(30)
            .run(|| batched.run(&params, &images).unwrap());
        report.note(format!(
            "{arch}: batched throughput {:.0} images/s vs single {:.0} images/s",
            b as f64 / res.mean_secs,
            1.0 / report.results()[report.results().len() - 2].mean_secs,
        ));
        report.add(res);
    }
    report.print();
}
