//! Bench: thread scaling (paper Figs 5/7/8/9).
//!
//! Two parts:
//! 1. real CHAOS training wall-clock at 1/2/4/8 workers on this host —
//!    on the single-core container this measures coordination *overhead*
//!    (lock traffic, store publication), not parallel speedup, which is
//!    exactly what it documents;
//! 2. the simulated Xeon Phi sweep that regenerates the paper's scaling
//!    curves (the substitution of DESIGN.md §2).

use chaos_phi::bench::{Bench, Report};
use chaos_phi::chaos::{ChaosPolicy, Trainer};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::phisim::speedup_table;

fn main() {
    let mut report = Report::new("thread_scaling — real host + simulated Phi");

    // Part 1: real coordination overhead on this host.
    let net = Network::new(ArchSpec::small());
    let train_set = generate_synthetic(300, 1, &SynthConfig::default());
    let test_set = generate_synthetic(60, 2, &SynthConfig::default());
    for threads in [1usize, 2, 4, 8] {
        let cfg = TrainConfig {
            epochs: 1,
            threads,
            eta0: 0.01,
            eta_decay: 0.9,
            seed: 5,
            validation_fraction: 0.0,
            eval_batch: 32,
            ..TrainConfig::default()
        };
        report.add(
            Bench::new(format!("real/chaos_epoch/{threads}t"))
                .warmup(1)
                .iters(3)
                .run(|| {
                    Trainer::new()
                        .network(net.clone())
                        .config(cfg.clone())
                        .policy(ChaosPolicy)
                        .run(&train_set, &test_set)
                        .unwrap()
                }),
        );
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    report.note(format!(
        "host has {cores} core(s): flat wall-clock across worker counts is expected — this measures coordination overhead, not speedup"
    ));

    // Part 2: the simulated Phi speedup sweep.
    for arch in ["small", "medium", "large"] {
        let rows = speedup_table(arch).unwrap();
        let line: Vec<String> = rows
            .iter()
            .map(|r| format!("{}T={:.1}x", r.threads, r.vs_phi_1t))
            .collect();
        report.note(format!("phisim {arch} vs Phi-1T: {}", line.join("  ")));
    }
    let large = speedup_table("large").unwrap();
    let r244 = large.iter().find(|r| r.threads == 244).unwrap();
    report.note(format!(
        "headline (large, 244T): {:.1}x vs Phi 1T (paper 103x), {:.1}x vs E5 (paper 14x), {:.1}x vs i5 (paper 58x)",
        r244.vs_phi_1t, r244.vs_xeon_e5, r244.vs_core_i5
    ));
    report.print();
}
