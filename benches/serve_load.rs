//! Bench: the serving tier under concurrent client load — request
//! throughput and latency percentiles swept over worker-pool sizes.
//!
//! Each configuration spawns a fresh native-engine server (tiny net,
//! batch cap 8) and drives `requests` predictions from `clients`
//! concurrent client threads; the server's own fixed-bucket histograms
//! supply the latency/exec-time distributions, so the bench doubles as an
//! end-to-end exercise of the bounded metrics path.
//!
//! Output: a markdown report on stdout **and** machine-readable
//! `BENCH_serve.json` (schema self-checked after writing, smoke-tested in
//! CI):
//!
//! ```json
//! {
//!   "bench": "serve_load", "requests": N, "batch": 8,
//!   "rows": [{"workers": W, "clients": C, "mean_secs": s,
//!             "req_per_sec": r, "p50_us": p, "p99_us": q,
//!             "exec_mean_us": e, "mean_batch_fill": f}, ...]
//! }
//! ```
//!
//! Run: `cargo bench --bench serve_load [-- --smoke] [-- --out FILE]`

use chaos_phi::bench::{Bench, Report};
use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::serve::{Engine, Server, ServerConfig};
use chaos_phi::util::Json;
use std::time::Duration;

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
const BATCH: usize = 8;

/// Drive `requests` predictions through the server from `clients`
/// concurrent threads; returns a checksum so the work cannot be elided.
fn drive(server: &Server, images: &Dataset, requests: usize, clients: usize) -> f64 {
    let sums: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let handle = server.handle();
                s.spawn(move || {
                    let mut sum = 0.0f64;
                    let mut i = c;
                    while i < requests {
                        let row = handle.predict(images.image(i % images.len())).expect("predict");
                        sum += row[0] as f64;
                        i += clients;
                    }
                    sum
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    sums.iter().sum()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_serve.json".to_string());

    let (requests, clients, iters) = if smoke { (64, 4, 1) } else { (2048, 8, 3) };

    let net = Network::from_name("tiny").unwrap();
    let params = net.init_params(1);
    let side = net.arch.input_side();
    let images = generate_synthetic(256.min(requests), 7, &SynthConfig::default()).resize(side);

    let mut report = Report::new(format!(
        "serve_load — {requests} requests, {clients} clients, batch cap {BATCH}, workers ∈ {WORKER_COUNTS:?}"
    ));

    let mut rows: Vec<Json> = Vec::new();
    for workers in WORKER_COUNTS {
        let server = Server::spawn(
            Engine::Native { net: net.clone(), params: params.clone(), batch: BATCH },
            ServerConfig {
                max_delay: Duration::from_micros(500),
                workers,
                ..Default::default()
            },
        )
        .expect("spawn server");
        let r = Bench::new(format!("serve/W={workers}/C={clients}"))
            .warmup(1)
            .iters(iters)
            .run(|| drive(&server, &images, requests, clients));
        let rate = requests as f64 / r.mean_secs;
        // The server's own histograms (accumulated over warmup + iters)
        // supply the latency shape.
        let m = server.handle().metrics.snapshot();
        report.note(format!(
            "W={workers}: {rate:.0} req/s, p50 {:.0}µs p99 {:.0}µs, exec mean {:.0}µs, fill {:.2}",
            m.p50_us, m.p99_us, m.exec_mean_us, m.mean_batch_fill
        ));
        rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("clients", Json::num(clients as f64)),
            ("mean_secs", Json::num(r.mean_secs)),
            ("req_per_sec", Json::num(rate)),
            ("p50_us", Json::num(m.p50_us)),
            ("p99_us", Json::num(m.p99_us)),
            ("exec_mean_us", Json::num(m.exec_mean_us)),
            ("mean_batch_fill", Json::num(m.mean_batch_fill)),
        ]));
        report.add(r);
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("serve_load")),
        ("smoke", Json::num(u32::from(smoke))),
        ("requests", Json::num(requests as f64)),
        ("batch", Json::num(BATCH as f64)),
        ("rows", Json::arr(rows)),
    ]);
    std::fs::write(&out_path, doc.pretty()).expect("write BENCH_serve.json");

    // Schema self-check: re-parse what we wrote so CI catches rot without
    // external tooling.
    let parsed = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).expect("valid JSON");
    assert_eq!(parsed.req("bench").unwrap().as_str(), Some("serve_load"));
    let rows = parsed.req("rows").unwrap().as_arr().expect("rows array");
    assert_eq!(rows.len(), WORKER_COUNTS.len());
    for row in rows {
        assert!(row.req("workers").unwrap().as_f64().unwrap() >= 1.0);
        assert!(row.req("req_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let p50 = row.req("p50_us").unwrap().as_f64().unwrap();
        let p99 = row.req("p99_us").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p99 >= p50, "histogram percentiles out of order: {p50} / {p99}");
        assert!(row.req("mean_batch_fill").unwrap().as_f64().unwrap() > 0.0);
    }
    println!("\nwrote {out_path}");

    report.print();
}
