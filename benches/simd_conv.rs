//! Bench: scalar vs vectorizable convolution inner loop — the measured
//! counterpart of the paper's Listing 1 (vectorization report, estimated
//! 3.98× speedup of the partial-derivative update loop).
//!
//! The "scalar" variant uses strided index arithmetic whose bounds checks
//! defeat the auto-vectorizer; the "vector" variant is the production
//! kernel's contiguous-slice saxpy/dot shape.

use chaos_phi::bench::{Bench, Report};
use chaos_phi::nn::conv::{conv_backward, conv_forward, ConvShape};
use chaos_phi::util::Pcg32;

/// Deliberately scalar conv forward (strided index arithmetic).
fn conv_forward_scalar(
    s: &ConvShape,
    input: &[f32],
    weights: &[f32],
    biases: &[f32],
    out: &mut [f32],
) {
    let os = s.out_side;
    let is = s.in_side;
    let k = s.kernel;
    for m in 0..s.out_maps {
        for y in 0..os {
            for x in 0..os {
                let mut acc = biases[m];
                for j in 0..s.in_maps {
                    for ky in 0..k {
                        for kx in 0..k {
                            acc += weights[((m * s.in_maps + j) * k + ky) * k + kx]
                                * input[j * is * is + (y + ky) * is + (x + kx)];
                        }
                    }
                }
                out[m * os * os + y * os + x] = acc;
            }
        }
    }
}

fn main() {
    let mut report = Report::new("simd_conv — scalar vs vectorized conv loops (Listing 1)");
    // The medium net's second conv layer (the paper's hot-spot geometry).
    let s = ConvShape::valid(20, 13, 40, 5);
    let mut rng = Pcg32::seeded(3);
    let input: Vec<f32> = (0..s.in_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let biases: Vec<f32> = (0..s.out_maps).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut out = vec![0.0f32; s.out_len()];

    let scalar = Bench::new("conv_fwd/scalar")
        .warmup(5)
        .iters(60)
        .run(|| conv_forward_scalar(&s, &input, &weights, &biases, &mut out));
    let vectored = Bench::new("conv_fwd/vectorized")
        .warmup(5)
        .iters(60)
        .run(|| conv_forward(&s, &input, &weights, &biases, &mut out));
    let ratio = scalar.mean_secs / vectored.mean_secs;
    report.add(scalar);
    report.add(vectored);

    // Backward (the partial-derivative update loop of Listing 1).
    let delta = vec![1.0f32; s.out_len()];
    let mut wg = vec![0.0f32; s.weight_len()];
    let mut bg = vec![0.0f32; s.out_maps];
    let mut din = vec![0.0f32; s.in_len()];
    report.add(Bench::new("conv_bwd/vectorized").warmup(5).iters(60).run(|| {
        wg.fill(0.0);
        bg.fill(0.0);
        conv_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut din)
    }));

    report.note(format!(
        "forward vector/scalar speedup: {ratio:.2}x (paper's compiler estimate for the bwd update loop: 3.98x)"
    ));
    report.print();
}
