//! Bench: per-layer forward/backward costs (paper Table 1 / Table 5 on
//! this host). One sample's fwd+bwd per architecture, plus the per-layer
//! split, measured with the in-crate harness.

use chaos_phi::bench::{Bench, Report};
use chaos_phi::config::ArchSpec;
use chaos_phi::nn::Network;
use chaos_phi::util::timer::{LayerClass, LayerTimes};
use chaos_phi::util::Pcg32;

fn main() {
    let mut report = Report::new("layer_times — per-sample costs per architecture");
    for name in ["tiny", "small", "medium", "large"] {
        let net = Network::new(ArchSpec::by_name(name).unwrap());
        let mut params = net.init_params(1);
        let mut scratch = net.scratch();
        let side = net.arch.input_side();
        let mut rng = Pcg32::seeded(2);
        let img: Vec<f32> = (0..side * side).map(|_| rng.uniform(-1.0, 1.0)).collect();

        let iters = if name == "large" { 8 } else { 40 };
        report.add(
            Bench::new(format!("{name}/sgd_step"))
                .warmup(2)
                .iters(iters)
                .run(|| net.sgd_step(&mut params, &img, 3, 1e-4, &mut scratch, None)),
        );

        // Layer-class split over a fixed batch of steps.
        let timers = LayerTimes::new();
        for _ in 0..iters {
            net.sgd_step(&mut params, &img, 3, 1e-4, &mut scratch, Some(&timers));
        }
        let total = timers.total_secs();
        let conv =
            timers.get_secs(LayerClass::ConvForward) + timers.get_secs(LayerClass::ConvBackward);
        report.note(format!(
            "{name}: conv {:.1}% of layer time (fwd {:.3}s bwd {:.3}s of {:.3}s total) — paper Table 1: 93.7% (small)",
            100.0 * conv / total,
            timers.get_secs(LayerClass::ConvForward),
            timers.get_secs(LayerClass::ConvBackward),
            total,
        ));
    }
    report.print();
}
