//! Bench: the Xeon Phi simulator itself — cost of a full paper-scale
//! sweep, plus the regenerated Table 5/6 summaries (shape checks that
//! `cargo bench` prints alongside timings).

use chaos_phi::bench::{Bench, Report};
use chaos_phi::phisim::{simulate, SimConfig, PAPER_THREAD_COUNTS};

fn main() {
    let mut report = Report::new("phisim_sweep — simulator cost + Table 5/6 summaries");

    for arch in ["small", "medium", "large"] {
        report.add(
            Bench::new(format!("simulate/{arch}/244t"))
                .warmup(2)
                .iters(10)
                .run(|| simulate(&SimConfig::paper(arch, 244)).unwrap()),
        );
    }
    report.add(
        Bench::new("simulate/large/full_sweep")
            .warmup(1)
            .iters(3)
            .run(|| {
                for &p in &PAPER_THREAD_COUNTS {
                    simulate(&SimConfig::paper("large", p)).unwrap();
                }
            }),
    );

    // Table-5 style summary at 244 threads.
    let r = simulate(&SimConfig::paper("large", 244)).unwrap();
    let c = r.layer_class_secs();
    report.note(format!(
        "large@244T layer classes: BPC {:.0}s ({:.1}%), FPC {:.0}s ({:.1}%), BPF {:.1}s, FPF {:.2}s — paper: 506s/88.5%, 55s/9.6%, 7.8s, 0.23s",
        c.bpc,
        100.0 * c.bpc / c.total(),
        c.fpc,
        100.0 * c.fpc / c.total(),
        c.bpf,
        c.fpf,
    ));
    report.print();
}
