//! Bench: per-sample vs minibatched training throughput, B ∈ {8, 32}, on
//! the paper "small" architecture.
//!
//! This is the measurement behind the minibatched back-propagation stack:
//! a `minibatch:B` worker claims B-sample chunks and drives one
//! `BatchPlan` forward/backward per chunk, so every layer's parameter span
//! is read once per chunk (weight-stationary kernels in both directions)
//! instead of once per image per pass. Throughput should rise with B while
//! the gradients stay bit-identical to per-sample accumulation (enforced
//! by rust/tests/batch_backward.rs).
//!
//! Output: a markdown report on stdout **and** machine-readable
//! `BENCH_train.json` (schema self-checked after writing, smoke-tested in
//! CI):
//!
//! ```json
//! {
//!   "bench": "train_minibatch", "arch": "small", "images": 256,
//!   "epochs": 2, "threads": 4,
//!   "per_sample": {"policy": "chaos", "train_secs": s, "images_per_sec": r},
//!   "minibatch": [{"batch": B, "train_secs": s, "images_per_sec": r,
//!                  "speedup_vs_per_sample": x, "final_train_loss": l}, ...]
//! }
//! ```
//!
//! Run: `cargo bench --bench train_minibatch [-- --smoke] [-- --out FILE]`

use chaos_phi::chaos::Trainer;
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
use chaos_phi::util::Json;

const BATCH_SIZES: [usize; 2] = [8, 32];

/// One training run; returns (summed training-phase seconds, final epoch's
/// mean train loss). Eval phases are minimized (no validation split, tiny
/// test set) so the measurement isolates the training phase.
fn train_once(
    policy: &str,
    trn: &Dataset,
    tst: &Dataset,
    threads: usize,
    epochs: usize,
) -> (f64, f64) {
    let cfg = TrainConfig {
        epochs,
        threads,
        eta0: 0.001,
        eta_decay: 0.9,
        seed: 0xBE7C4,
        validation_fraction: 0.0,
        eval_batch: 32,
        ..TrainConfig::default()
    };
    let run = Trainer::new()
        .arch(ArchSpec::small())
        .config(cfg)
        .policy_name(policy)
        .expect("policy resolves")
        .run(trn, tst)
        .expect("training run");
    let train_secs: f64 = run.epochs.iter().map(|e| e.train_secs).sum();
    let last = run.final_epoch();
    (train_secs, last.train.loss / last.train.images.max(1) as f64)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_train.json".to_string());

    let (images_n, epochs, threads) = if smoke { (48, 1, 2) } else { (256, 2, 4) };

    let side = ArchSpec::small().input_side();
    let trn = generate_synthetic(images_n, 7, &SynthConfig::default()).resize(side);
    let tst = generate_synthetic(16, 8, &SynthConfig::default()).resize(side);

    let mut report = chaos_phi::bench::Report::new(format!(
        "train_minibatch — per-sample vs minibatch training over {images_n} images × {epochs} \
         epochs (arch small, {threads} threads)"
    ));

    let (ps_secs, ps_loss) = train_once("chaos", &trn, &tst, threads, epochs);
    let total_images = (images_n * epochs) as f64;
    let ps_rate = total_images / ps_secs;
    report.note(format!(
        "per-sample (chaos): {ps_rate:.0} images/s ({ps_secs:.2}s train, mean loss {ps_loss:.3})"
    ));

    let mut rows: Vec<Json> = Vec::new();
    for b in BATCH_SIZES {
        let (secs, loss) = train_once(&format!("minibatch:{b}"), &trn, &tst, threads, epochs);
        let rate = total_images / secs;
        let speedup = ps_secs / secs;
        assert!(loss.is_finite() && loss > 0.0, "minibatch:{b} training diverged");
        report.note(format!(
            "minibatch:{b}: {rate:.0} images/s, {speedup:.2}× vs per-sample (mean loss {loss:.3})"
        ));
        rows.push(Json::obj(vec![
            ("batch", Json::num(b as f64)),
            ("train_secs", Json::num(secs)),
            ("images_per_sec", Json::num(rate)),
            ("speedup_vs_per_sample", Json::num(speedup)),
            ("final_train_loss", Json::num(loss)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("train_minibatch")),
        ("arch", Json::str("small")),
        ("smoke", Json::num(u32::from(smoke))),
        ("images", Json::num(images_n as f64)),
        ("epochs", Json::num(epochs as f64)),
        ("threads", Json::num(threads as f64)),
        (
            "per_sample",
            Json::obj(vec![
                ("policy", Json::str("chaos")),
                ("train_secs", Json::num(ps_secs)),
                ("images_per_sec", Json::num(ps_rate)),
            ]),
        ),
        ("minibatch", Json::arr(rows)),
    ]);
    std::fs::write(&out_path, doc.pretty()).expect("write BENCH_train.json");

    // Schema self-check: re-parse what we wrote so CI catches rot without
    // external tooling.
    let parsed = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).expect("valid JSON");
    assert_eq!(parsed.req("bench").unwrap().as_str(), Some("train_minibatch"));
    assert!(
        parsed.req("per_sample").unwrap().req("images_per_sec").unwrap().as_f64().unwrap() > 0.0
    );
    let rows = parsed.req("minibatch").unwrap().as_arr().expect("minibatch array");
    assert_eq!(rows.len(), BATCH_SIZES.len());
    for row in rows {
        assert!(row.req("speedup_vs_per_sample").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.req("final_train_loss").unwrap().as_f64().unwrap() > 0.0);
    }
    println!("\nwrote {out_path}");

    report.print();
}
