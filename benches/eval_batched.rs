//! Bench: per-sample vs batched forward-only evaluation, plus native-serve
//! throughput, swept over batch sizes B ∈ {1, 8, 32} on the paper "small"
//! architecture.
//!
//! This is the measurement behind the batched execution stack: the batched
//! path loads every layer's parameters once per batch (weight-stationary
//! kernels), so images/sec should rise with B while staying bit-identical
//! to the per-sample path (enforced by rust/tests/batch_forward.rs — this
//! bench asserts it once more on real data as a sanity gate).
//!
//! Output: a markdown report on stdout **and** machine-readable
//! `BENCH_eval.json` (schema self-checked after writing, smoke-tested in
//! CI):
//!
//! ```json
//! {
//!   "bench": "eval_batched", "arch": "small", "images": 256,
//!   "per_sample": {"mean_secs": s, "images_per_sec": r},
//!   "batched": [{"batch": B, "mean_secs": s, "images_per_sec": r,
//!                "speedup_vs_per_sample": x}, ...],
//!   "serve": [{"batch": B, "requests": n, "clients": c, "req_per_sec": r}, ...]
//! }
//! ```
//!
//! Run: `cargo bench --bench eval_batched [-- --smoke] [-- --out FILE]`

use chaos_phi::bench::{Bench, Report};
use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
use chaos_phi::nn::Network;
use chaos_phi::serve::{Engine, Server, ServerConfig};
use chaos_phi::util::{Json, Stopwatch};

const BATCH_SIZES: [usize; 3] = [1, 8, 32];

fn eval_per_sample(net: &Network, params: &[f32], data: &Dataset) -> usize {
    let mut scratch = net.scratch();
    let mut errors = 0;
    for i in 0..data.len() {
        let probs = net.forward(&params, data.image(i), &mut scratch, None);
        errors += usize::from(chaos_phi::tensor::argmax(probs) != data.label(i));
    }
    errors
}

fn eval_batched(net: &Network, params: &[f32], data: &Dataset, batch: usize) -> usize {
    let plan = net.batch_plan(batch).unwrap();
    let mut scratch = plan.scratch();
    let classes = net.num_classes();
    let mut errors = 0;
    let mut idx = 0;
    while idx < data.len() {
        let b = batch.min(data.len() - idx);
        for slot in 0..b {
            plan.stage_image(&mut scratch, slot, data.image(idx + slot));
        }
        let probs = plan.forward_staged(&params, b, &mut scratch, None);
        for (s, row) in probs.chunks_exact(classes).enumerate() {
            errors += usize::from(chaos_phi::tensor::argmax(row) != data.label(idx + s));
        }
        idx += b;
    }
    errors
}

fn serve_throughput(net: &Network, params: &[f32], batch: usize, requests: usize) -> (f64, usize) {
    let clients = 8usize;
    let server = Server::spawn(
        Engine::Native { net: net.clone(), params: params.to_vec(), batch },
        ServerConfig { max_delay: std::time::Duration::from_millis(1), ..Default::default() },
    )
    .expect("native server");
    let side = net.arch.input_side();
    let images = generate_synthetic(requests, 17, &SynthConfig::default()).resize(side);
    let sw = Stopwatch::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = server.handle();
            let images = &images;
            s.spawn(move || {
                let mut i = c;
                while i < requests {
                    handle.predict(images.image(i)).expect("predict");
                    i += clients;
                }
            });
        }
    });
    (requests as f64 / sw.elapsed_secs(), clients)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_eval.json".to_string());

    let (images_n, iters, serve_requests) = if smoke { (32, 2, 32) } else { (256, 8, 1024) };

    let net = Network::from_name("small").unwrap();
    let params = net.init_params(1);
    let side = net.arch.input_side();
    let data = generate_synthetic(images_n, 7, &SynthConfig::default()).resize(side);

    let mut report = Report::new(format!(
        "eval_batched — per-sample vs batched eval over {images_n} images (arch small)"
    ));

    // Sanity gate: both paths must classify identically (bit-identity).
    let base_errors = eval_per_sample(&net, &params, &data);
    for b in BATCH_SIZES {
        assert_eq!(
            eval_batched(&net, &params, &data, b),
            base_errors,
            "batched eval (B={b}) diverged from per-sample predictions"
        );
    }

    let per_sample = Bench::new("eval/per-sample")
        .warmup(1)
        .iters(iters)
        .run(|| eval_per_sample(&net, &params, &data));
    let per_sample_rate = images_n as f64 / per_sample.mean_secs;
    report.add(per_sample.clone());

    let mut batched_rows: Vec<Json> = Vec::new();
    for b in BATCH_SIZES {
        let r = Bench::new(format!("eval/batched/B={b}"))
            .warmup(1)
            .iters(iters)
            .run(|| eval_batched(&net, &params, &data, b));
        let rate = images_n as f64 / r.mean_secs;
        let speedup = per_sample.mean_secs / r.mean_secs;
        report.note(format!(
            "B={b}: {rate:.0} images/s, {speedup:.2}× vs per-sample ({:.0} images/s)",
            per_sample_rate
        ));
        batched_rows.push(Json::obj(vec![
            ("batch", Json::num(b as f64)),
            ("mean_secs", Json::num(r.mean_secs)),
            ("images_per_sec", Json::num(rate)),
            ("speedup_vs_per_sample", Json::num(speedup)),
        ]));
        report.add(r);
    }

    let mut serve_rows: Vec<Json> = Vec::new();
    for b in BATCH_SIZES {
        let sw = Stopwatch::start();
        let (req_per_sec, clients) = serve_throughput(&net, &params, b, serve_requests);
        report.note(format!(
            "serve B={b}: {req_per_sec:.0} req/s ({serve_requests} requests, {clients} clients, \
             {:.2}s)",
            sw.elapsed_secs()
        ));
        serve_rows.push(Json::obj(vec![
            ("batch", Json::num(b as f64)),
            ("requests", Json::num(serve_requests as f64)),
            ("clients", Json::num(clients as f64)),
            ("req_per_sec", Json::num(req_per_sec)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("eval_batched")),
        ("arch", Json::str("small")),
        ("smoke", Json::num(u32::from(smoke))),
        ("images", Json::num(images_n as f64)),
        (
            "per_sample",
            Json::obj(vec![
                ("mean_secs", Json::num(per_sample.mean_secs)),
                ("images_per_sec", Json::num(per_sample_rate)),
            ]),
        ),
        ("batched", Json::arr(batched_rows)),
        ("serve", Json::arr(serve_rows)),
    ]);
    std::fs::write(&out_path, doc.pretty()).expect("write BENCH_eval.json");

    // Schema self-check: re-parse what we wrote so CI catches rot without
    // external tooling.
    let parsed = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).expect("valid JSON");
    assert_eq!(parsed.req("bench").unwrap().as_str(), Some("eval_batched"));
    assert!(parsed.req("per_sample").unwrap().req("images_per_sec").unwrap().as_f64().unwrap() > 0.0);
    let batched = parsed.req("batched").unwrap().as_arr().expect("batched array");
    assert_eq!(batched.len(), BATCH_SIZES.len());
    for row in batched {
        assert!(row.req("speedup_vs_per_sample").unwrap().as_f64().unwrap() > 0.0);
    }
    assert_eq!(parsed.req("serve").unwrap().as_arr().map(|a| a.len()), Some(BATCH_SIZES.len()));
    println!("\nwrote {out_path}");

    report.print();
}
