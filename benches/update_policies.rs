//! Bench: CHAOS vs the strategy baselines of §4.1 (A–D ablation).
//! Measures one training epoch per policy at 4 workers — wall-clock,
//! publication counts, and resulting training loss, on identical data and
//! seeds.
//!
//! Policies come from the registry (`chaos::policy::names`), so an impl
//! registered through `chaos::policy::register` is benchmarked
//! automatically.

use chaos_phi::bench::{Bench, Report};
use chaos_phi::chaos::{policy, Trainer};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, SynthConfig};
use chaos_phi::nn::Network;

fn main() {
    let mut report = Report::new("update_policies — policy ablation (4 workers, 1 epoch)");
    let net = Network::new(ArchSpec::small());
    let train_set = generate_synthetic(400, 9, &SynthConfig::default());
    let test_set = generate_synthetic(100, 10, &SynthConfig::default());
    let cfg = TrainConfig {
        epochs: 1,
        threads: 4,
        eta0: 0.01,
        eta_decay: 0.9,
        seed: 21,
        validation_fraction: 0.0,
        eval_batch: 32,
        ..TrainConfig::default()
    };

    for name in policy::names() {
        // A registered factory may require a ':' argument; such policies
        // can't be instantiated from the bare name, so skip with a note.
        let sequential = match policy::from_name(&name) {
            Ok(p) => p.is_sequential(),
            Err(e) => {
                report.note(format!("{name}: skipped ({e})"));
                continue;
            }
        };
        let cfg = if sequential { TrainConfig { threads: 1, ..cfg.clone() } } else { cfg.clone() };
        let mut last_loss = 0.0;
        let mut pubs = 0;
        report.add(
            Bench::new(format!("epoch/{name}"))
                .warmup(1)
                .iters(3)
                .run(|| {
                    let r = Trainer::new()
                        .network(net.clone())
                        .config(cfg.clone())
                        .policy_name(&name)
                        .unwrap()
                        .run(&train_set, &test_set)
                        .unwrap();
                    last_loss = r.final_epoch().train.loss;
                    pubs = r.publications;
                }),
        );
        report.note(format!("{name}: train loss {last_loss:.1}, {pubs} publications"));
    }
    report.note("CHAOS's per-layer locking costs little over pure HogWild! while keeping updates exact; delayed-rr serializes whole samples; averaged adds barriers.");
    report.print();
}
