//! Bench: batch-lane SIMD training kernels — one full minibatch step
//! (batched forward + backward) swept over B ∈ {1, 8, 32} × the two
//! accumulation policies (`--math exact|fast`), on a plain-conv paper
//! net and a padded/strided net that routes through im2col+GEMM.
//!
//! This is the measurement behind the batch-lane rework: `Exact` keeps
//! the per-sample accumulation order (bit-identity enforced by
//! rust/tests/batch_forward.rs and batch_backward.rs), `Fast` allows the
//! reassociated kernels — im2col staging for general conv and the
//! KC/MR cache-blocked fc GEMM — so its rows should only go up from the
//! exact ones. A numeric sanity gate asserts fast probabilities stay
//! within a small relative error of exact before any timing runs.
//!
//! Output: a markdown report on stdout **and** machine-readable
//! `BENCH_simd.json` (schema self-checked after writing, smoke-tested in
//! CI):
//!
//! ```json
//! {
//!   "bench": "simd_batch", "images": 128,
//!   "archs": [{"arch": "small", "rows": [
//!     {"batch": B, "math": "exact"|"fast", "mean_secs": s,
//!      "images_per_sec": r, "speedup_vs_exact_b1": x}, ...]}, ...]
//! }
//! ```
//!
//! Run: `cargo bench --bench simd_batch [-- --smoke] [-- --out FILE]`

use chaos_phi::bench::{Bench, Report};
use chaos_phi::config::{Act, ArchSpec, LayerSpec};
use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
use chaos_phi::nn::{MathPolicy, Network};
use chaos_phi::util::Json;

const BATCH_SIZES: [usize; 3] = [1, 8, 32];
const POLICIES: [MathPolicy; 2] = [MathPolicy::Exact, MathPolicy::Fast];

/// A padded + strided net: its first conv leaves the plain
/// weight-stationary kernels and exercises the im2col+GEMM route.
fn general_arch() -> ArchSpec {
    ArchSpec {
        name: "bench-general".into(),
        layers: vec![
            LayerSpec::Input { side: 29 },
            LayerSpec::conv_ex(6, 5, 2, 2, Act::Relu), // stride-2/pad-2: 15x15
            LayerSpec::MaxPool { kernel: 3 },          // 5x5
            LayerSpec::fc_act(40, Act::Relu),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    }
}

/// One epoch of minibatch steps over the whole dataset: stage, forward,
/// backward, consume the batch-summed gradients. Returns a gradient
/// checksum so the optimizer cannot dead-code the work away.
fn train_steps(
    net: &Network,
    params: &[f32],
    data: &Dataset,
    batch: usize,
    math: MathPolicy,
) -> f64 {
    let plan = net.batch_plan(batch).unwrap().with_math(math);
    let mut scratch = plan.scratch_seeded(42);
    scratch.train_mode = true;
    let mut sink = 0.0f64;
    let mut labels = Vec::with_capacity(batch);
    let mut idx = 0;
    while idx < data.len() {
        let b = batch.min(data.len() - idx);
        for slot in 0..b {
            plan.stage_image(&mut scratch, slot, data.image(idx + slot));
        }
        plan.forward_staged(&params, b, &mut scratch, None);
        labels.clear();
        labels.extend((0..b).map(|s| data.label(idx + s)));
        plan.backward(&params, &labels, b, &mut scratch, None, |_, _, grads| {
            sink += grads.iter().take(4).map(|&g| g as f64).sum::<f64>();
        });
        idx += b;
    }
    sink
}

/// Numeric gate: fast-math probabilities must stay within a small
/// relative error of the exact ones on real data (the batch suites pin
/// the tight property; this re-asserts it on the benched nets).
fn assert_fast_close_to_exact(net: &Network, params: &[f32], data: &Dataset, batch: usize) {
    let n = batch.min(data.len());
    let il = net.dims[0].out_len();
    let images: Vec<f32> = (0..n).flat_map(|i| data.image(i).to_vec()).collect();
    let exact_plan = net.batch_plan(n).unwrap();
    let mut exact_scratch = exact_plan.scratch_seeded(0);
    let exact = exact_plan.forward(&params, &images[..n * il], n, &mut exact_scratch, None).to_vec();
    let fast_plan = net.batch_plan(n).unwrap().with_math(MathPolicy::Fast);
    let mut fast_scratch = fast_plan.scratch_seeded(0);
    let fast = fast_plan.forward(&params, &images[..n * il], n, &mut fast_scratch, None);
    for (i, (&e, &f)) in exact.iter().zip(fast).enumerate() {
        let tol = 1e-5f32 * e.abs().max(f.abs()).max(1e-3);
        assert!(
            (e - f).abs() <= tol,
            "{}: fast prob {i} drifted from exact: {e} vs {f}",
            net.arch.name
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_simd.json".to_string());

    let (images_n, iters) = if smoke { (32, 2) } else { (128, 6) };

    let nets = [Network::from_name("small").unwrap(), Network::new(general_arch())];

    let mut report = Report::new(format!(
        "simd_batch — minibatch step over {images_n} images, B ∈ {BATCH_SIZES:?} × exact/fast"
    ));

    let mut arch_docs: Vec<Json> = Vec::new();
    for net in &nets {
        let params = net.init_params(1);
        let side = net.arch.input_side();
        let data = generate_synthetic(images_n, 7, &SynthConfig::default()).resize(side);

        assert_fast_close_to_exact(net, &params, &data, *BATCH_SIZES.last().unwrap());

        let mut rows: Vec<Json> = Vec::new();
        let mut exact_b1_secs = None;
        for b in BATCH_SIZES {
            for math in POLICIES {
                let r = Bench::new(format!("{}/B={b}/{}", net.arch.name, math.name()))
                    .warmup(1)
                    .iters(iters)
                    .run(|| train_steps(net, &params, &data, b, math));
                let rate = images_n as f64 / r.mean_secs;
                if b == 1 && math == MathPolicy::Exact {
                    exact_b1_secs = Some(r.mean_secs);
                }
                let speedup = exact_b1_secs.expect("B=1 exact runs first") / r.mean_secs;
                report.note(format!(
                    "{} B={b} {}: {rate:.0} images/s, {speedup:.2}× vs exact B=1",
                    net.arch.name,
                    math.name()
                ));
                rows.push(Json::obj(vec![
                    ("batch", Json::num(b as f64)),
                    ("math", Json::str(math.name())),
                    ("mean_secs", Json::num(r.mean_secs)),
                    ("images_per_sec", Json::num(rate)),
                    ("speedup_vs_exact_b1", Json::num(speedup)),
                ]));
                report.add(r);
            }
        }
        arch_docs.push(Json::obj(vec![
            ("arch", Json::str(net.arch.name.as_str())),
            ("rows", Json::arr(rows)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("simd_batch")),
        ("smoke", Json::num(u32::from(smoke))),
        ("images", Json::num(images_n as f64)),
        ("archs", Json::arr(arch_docs)),
    ]);
    std::fs::write(&out_path, doc.pretty()).expect("write BENCH_simd.json");

    // Schema self-check: re-parse what we wrote so CI catches rot without
    // external tooling.
    let parsed = Json::parse(&std::fs::read_to_string(&out_path).unwrap()).expect("valid JSON");
    assert_eq!(parsed.req("bench").unwrap().as_str(), Some("simd_batch"));
    let archs = parsed.req("archs").unwrap().as_arr().expect("archs array");
    assert_eq!(archs.len(), nets.len());
    for arch in archs {
        let rows = arch.req("rows").unwrap().as_arr().expect("rows array");
        assert_eq!(rows.len(), BATCH_SIZES.len() * POLICIES.len());
        for row in rows {
            let math = row.req("math").unwrap().as_str().unwrap();
            assert!(math == "exact" || math == "fast", "bad policy tag {math}");
            assert!(row.req("images_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(row.req("speedup_vs_exact_b1").unwrap().as_f64().unwrap() > 0.0);
        }
    }
    println!("\nwrote {out_path}");

    report.print();
}
