//! Bench: the static shard pass — plan, verify and price across the
//! paper architectures and shard counts. The pass runs on every
//! `chaos analyze --shards` invocation and inside CI sweeps, so it
//! should stay well under a millisecond even for the large net.

use chaos_phi::bench::{Bench, Report};
use chaos_phi::chaos::analysis::{plan_shards, verify_shards};
use chaos_phi::nn::Network;
use chaos_phi::perfmodel::{rank_plans, score_plan};

fn main() {
    let mut report = Report::new("shard_plan — plan/verify/score the static shard pass");

    for arch in ["small", "medium", "large"] {
        let net = Network::from_name(arch).unwrap();
        for shards in [2, 4, 8] {
            report.add(
                Bench::new(format!("plan/{arch}/{shards}s"))
                    .warmup(3)
                    .iters(50)
                    .run(|| plan_shards(&net, shards)),
            );
            let plan = plan_shards(&net, shards);
            report.add(
                Bench::new(format!("verify/{arch}/{shards}s"))
                    .warmup(3)
                    .iters(50)
                    .run(|| verify_shards(&net, &plan)),
            );
            report.add(
                Bench::new(format!("score/{arch}/{shards}s"))
                    .warmup(3)
                    .iters(50)
                    .run(|| score_plan(&net, &plan)),
            );
        }
    }

    // Ranking summary: which uniform shard count the cost model prefers
    // for the large net (shape check printed alongside the timings).
    let net = Network::from_name("large").unwrap();
    let plans: Vec<_> = [1, 2, 4, 8].iter().map(|&n| plan_shards(&net, n)).collect();
    let ranked = rank_plans(&net, &plans);
    let (best, score) = &ranked[0];
    report.note(format!(
        "large: best uniform plan = {} shard(s) — imbalance {:.3}, {:.3e} comm B/sample, proxy {:.3e} s/sample",
        plans[*best].shards,
        score.imbalance,
        score.comm_bytes,
        score.proxy_secs(),
    ));
    report.print();
}
