"""L1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""

from .conv2d import conv2d, conv2d_macs, conv2d_vmem_bytes
from .fc import fc
from .maxpool import maxpool

__all__ = ["conv2d", "conv2d_macs", "conv2d_vmem_bytes", "fc", "maxpool"]
