"""L1 Pallas kernels: fully-connected layer, forward and backward
(matvec / outer product on the MXU)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import INTERPRET


def _fc_fwd_kernel(x_ref, w_ref, b_ref, o_ref):
    # w [O, I] @ x [I] + b [O]
    o_ref[...] = (
        jnp.dot(w_ref[...], x_ref[...], preferred_element_type=jnp.float32) + b_ref[...]
    )


def _fc_bwd_kernel(x_ref, w_ref, g_ref, dx_ref, dw_ref, db_ref):
    x = x_ref[...]
    w = w_ref[...]
    g = g_ref[...]
    dx_ref[...] = jnp.dot(w.T, g, preferred_element_type=jnp.float32)
    dw_ref[...] = jnp.outer(g, x)
    db_ref[...] = g


def _fc_call(x, w, b):
    (i,) = x.shape
    o, i2 = w.shape
    assert i == i2, f"shape mismatch: x {x.shape} w {w.shape}"
    return pl.pallas_call(
        _fc_fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((o,), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b)


@jax.custom_vjp
def fc(x, w, b):
    """x [I], w [O,I], b [O] -> pre-activations [O] (differentiable)."""
    return _fc_call(x, w, b)


def _fc_vjp_fwd(x, w, b):
    return _fc_call(x, w, b), (x, w)


def _fc_vjp_bwd(residual, g):
    x, w = residual
    (i,) = x.shape
    (o,) = g.shape
    dx, dw, db = pl.pallas_call(
        _fc_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((i,), jnp.float32),
            jax.ShapeDtypeStruct((o, i), jnp.float32),
            jax.ShapeDtypeStruct((o,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, g)
    return dx, dw, db


fc.defvjp(_fc_vjp_fwd, _fc_vjp_bwd)
