"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest sweeps shapes/values drawn from
the Table-2 family (plus hypothesis-generated ones) and asserts the Pallas
kernels match to float tolerance. They are intentionally written with
`jax.lax` primitives — a completely different code path from the kernels'
im2col formulation.
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b):
    """Valid conv, stride 1, via lax.conv_general_dilated.

    x [C,H,W], w [M,C,k,k], b [M] -> [M,oh,ow].
    """
    out = jax.lax.conv_general_dilated(
        x[None],  # [1, C, H, W]
        w,  # [M, C, k, k] (OIHW)
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return out + b[:, None, None]


def maxpool_ref(x, k: int):
    """Window max via lax.reduce_window. x [C,H,W] -> [C,H//k,W//k]."""
    c, h, w = x.shape
    oh, ow = h // k, w // k
    cropped = x[:, : oh * k, : ow * k]
    return jax.lax.reduce_window(
        cropped,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, k, k),
        window_strides=(1, k, k),
        padding="VALID",
    )


def fc_ref(x, w, b):
    """x [I], w [O,I], b [O] -> [O]."""
    return w @ x + b


# --- activation / loss references shared with the L2 model -----------------

TANH_A = 1.7159
TANH_B = 2.0 / 3.0


def scaled_tanh(x):
    """LeCun tanh: 1.7159 · tanh(2x/3) — same constants as the rust nn."""
    return TANH_A * jnp.tanh(TANH_B * x)


def softmax_xent(logits, label):
    """Numerically stable softmax + cross-entropy; returns (probs, loss)."""
    z = logits - jnp.max(logits)
    e = jnp.exp(z)
    probs = e / jnp.sum(e)
    loss = -jnp.log(jnp.clip(probs[label], 1e-12, 1.0))
    return probs, loss
