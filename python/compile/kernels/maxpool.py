"""L1 Pallas kernels: non-overlapping max pooling (kernel k, stride k),
forward and backward.

The reshape-max formulation keeps the whole map in VMEM and reduces with
vector max ops — no gather/scatter in the forward. The backward routes each
output delta to the *first* maximum of its window (argmax one-hot), matching
the rust `nn::pool` switches semantics exactly so the two engines stay
numerically aligned even on ties.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import INTERPRET


def _windows(x, k: int, oh: int, ow: int):
    """[C,H,W] -> [C,oh,ow,k*k] window view (crops ragged tails)."""
    c = x.shape[0]
    x = x[:, : oh * k, : ow * k]
    return x.reshape(c, oh, k, ow, k).transpose(0, 1, 3, 2, 4).reshape(c, oh, ow, k * k)


def _maxpool_fwd_kernel(x_ref, o_ref, *, k: int, oh: int, ow: int):
    o_ref[...] = _windows(x_ref[...], k, oh, ow).max(axis=-1)


def _maxpool_bwd_kernel(x_ref, g_ref, dx_ref, *, k: int, oh: int, ow: int):
    x = x_ref[...]
    g = g_ref[...]
    c, h, w = x.shape
    win = _windows(x, k, oh, ow)  # [C,oh,ow,k*k]
    # First-argmax one-hot (ties resolved to the lowest flat index, like the
    # rust switches).
    am = jnp.argmax(win, axis=-1)
    onehot = jax.nn.one_hot(am, k * k, dtype=jnp.float32)
    routed = onehot * g[..., None]  # [C,oh,ow,k*k]
    # Back to image layout; pad ragged tail with zeros.
    dx_core = (
        routed.reshape(c, oh, ow, k, k)
        .transpose(0, 1, 3, 2, 4)
        .reshape(c, oh * k, ow * k)
    )
    dx_ref[...] = jnp.pad(dx_core, ((0, 0), (0, h - oh * k), (0, w - ow * k)))


def _maxpool_call(x, k: int):
    c, h, w = x.shape
    oh, ow = h // k, w // k
    assert oh > 0 and ow > 0, f"pool kernel {k} too large for {x.shape}"
    return pl.pallas_call(
        partial(_maxpool_fwd_kernel, k=k, oh=oh, ow=ow),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.float32),
        interpret=INTERPRET,
    )(x)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def maxpool(x, k: int):
    """x [C,H,W] -> [C, H//k, W//k] window maxima (differentiable)."""
    return _maxpool_call(x, k)


def _maxpool_vjp_fwd(x, k: int):
    return _maxpool_call(x, k), x


def _maxpool_vjp_bwd(k: int, x, g):
    c, h, w = x.shape
    oh, ow = h // k, w // k
    dx = pl.pallas_call(
        partial(_maxpool_bwd_kernel, k=k, oh=oh, ow=ow),
        out_shape=jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        interpret=INTERPRET,
    )(x, g)
    return (dx,)


maxpool.defvjp(_maxpool_vjp_fwd, _maxpool_vjp_bwd)
