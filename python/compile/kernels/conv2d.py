"""L1 Pallas kernels: valid 2-D convolution, forward and backward (the
paper's hot spot — Table 5 attributes ~88% of training time to conv
back-propagation).

Hardware adaptation (DESIGN.md §3): the paper vectorizes the convolution's
inner loops for the Xeon Phi's 512-bit VPU with ``#pragma omp simd``. On the
TPU model the same insight — turn the partial-derivative / weight-gradient
loops into dense vector arithmetic — maps to an im2col restructuring so the
multiply-accumulates run on the MXU systolic array:

  forward : out  = W[M, C·k²] @ patches[C·k², oh·ow]
  backward: dW   = g[M, oh·ow] @ patchesᵀ          (weight gradients)
            dx   = col2im( Wᵀ @ g )                (input deltas)

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path and
TPU efficiency is estimated analytically (EXPERIMENTS.md §Perf L1).

``pallas_call`` has no built-in reverse-mode rule, so the backward kernel is
attached with ``jax.custom_vjp`` — which is exactly how the paper structures
the computation too: an explicit backward pass, not autodiff.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Run Pallas in interpret mode everywhere (CPU-only container).
INTERPRET = True


def _conv2d_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, oh: int, ow: int):
    """One image: x [C,H,W], w [M,C,k,k], b [M] -> o [M,oh,ow]."""
    x = x_ref[...]
    c = x.shape[0]
    cols = []
    # k is a trace-time constant (≤ 6 for the paper's networks): the loop
    # unrolls into k² static slices — the VMEM-resident analogue of the
    # paper's kernel shifting over neurons.
    for ky in range(k):
        for kx in range(k):
            cols.append(x[:, ky : ky + oh, kx : kx + ow])
    # [C, k*k, oh, ow] -> [C*k*k, oh*ow]
    patches = jnp.stack(cols, axis=1).reshape(c * k * k, oh * ow)
    w = w_ref[...].reshape(-1, c * k * k)  # [M, C*k*k]
    acc = jnp.dot(w, patches, preferred_element_type=jnp.float32)
    o_ref[...] = (acc + b_ref[...][:, None]).reshape(-1, oh, ow)


def _conv2d_bwd_kernel(
    x_ref, w_ref, g_ref, dx_ref, dw_ref, db_ref, *, k: int, oh: int, ow: int
):
    """Backward: cotangent g [M,oh,ow] -> (dx [C,H,W], dw [M,C,k,k], db [M])."""
    x = x_ref[...]
    w = w_ref[...]
    g = g_ref[...]
    c = x.shape[0]
    m = w.shape[0]

    # Rebuild the forward's patch matrix (recompute-over-store: the patch
    # matrix is k² times the input and recomputing it keeps VMEM small).
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(x[:, ky : ky + oh, kx : kx + ow])
    patches = jnp.stack(cols, axis=1).reshape(c * k * k, oh * ow)

    gm = g.reshape(m, oh * ow)
    # Weight gradients: one MXU matmul.
    dw_ref[...] = jnp.dot(gm, patches.T, preferred_element_type=jnp.float32).reshape(
        m, c, k, k
    )
    # Bias gradients: row sums.
    db_ref[...] = jnp.sum(gm, axis=1)

    # Input deltas: dx_cols [C·k², oh·ow] = Wᵀ @ g, then col2im scatter-add
    # (k² shifted accumulations — the transpose of the forward's im2col).
    wm = w.reshape(m, c * k * k)
    dx_cols = jnp.dot(wm.T, gm, preferred_element_type=jnp.float32).reshape(
        c, k * k, oh, ow
    )
    dx = jnp.zeros_like(x)
    idx = 0
    for ky in range(k):
        for kx in range(k):
            dx = dx.at[:, ky : ky + oh, kx : kx + ow].add(dx_cols[:, idx])
            idx += 1
    dx_ref[...] = dx


def _conv2d_call(x, w, b):
    c, h, width = x.shape
    m, c2, k, k2 = w.shape
    assert c == c2 and k == k2, f"shape mismatch: x {x.shape} w {w.shape}"
    oh, ow = h - k + 1, width - k + 1
    return pl.pallas_call(
        partial(_conv2d_fwd_kernel, k=k, oh=oh, ow=ow),
        out_shape=jax.ShapeDtypeStruct((m, oh, ow), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b)


def _conv2d_bwd_call(x, w, g):
    c, h, width = x.shape
    m, _, k, _ = w.shape
    oh, ow = h - k + 1, width - k + 1
    return pl.pallas_call(
        partial(_conv2d_bwd_kernel, k=k, oh=oh, ow=ow),
        out_shape=(
            jax.ShapeDtypeStruct((c, h, width), jnp.float32),  # dx
            jax.ShapeDtypeStruct((m, c, k, k), jnp.float32),  # dw
            jax.ShapeDtypeStruct((m,), jnp.float32),  # db
        ),
        interpret=INTERPRET,
    )(x, w, g)


@jax.custom_vjp
def conv2d(x, w, b):
    """Valid convolution, stride 1: x [C,H,W], w [M,C,k,k], b [M].

    Returns pre-activations [M, H-k+1, W-k+1]. Differentiable via the
    explicit backward Pallas kernel.
    """
    return _conv2d_call(x, w, b)


def _conv2d_vjp_fwd(x, w, b):
    return _conv2d_call(x, w, b), (x, w)


def _conv2d_vjp_bwd(residual, g):
    x, w = residual
    dx, dw, db = _conv2d_bwd_call(x, w, g)
    return dx, dw, db


conv2d.defvjp(_conv2d_vjp_fwd, _conv2d_vjp_bwd)


def conv2d_vmem_bytes(c: int, h: int, m: int, k: int) -> int:
    """Estimated VMEM working set of the forward kernel in bytes (f32):
    input + patch matrix + weights + output. Used by the L1 efficiency
    estimate in EXPERIMENTS.md §Perf."""
    oh = h - k + 1
    patches = c * k * k * oh * oh
    return 4 * (c * h * h + patches + m * c * k * k + m * oh * oh)


def conv2d_macs(c: int, h: int, m: int, k: int) -> int:
    """Multiply-accumulate count of one forward convolution."""
    oh = h - k + 1
    return m * c * k * k * oh * oh
