"""L2: the paper's CNN architectures (Table 2) as JAX forward/backward
functions built on the L1 Pallas kernels.

The layer stacks, parameter layouts ([M,C,k,k] conv weights + [M] biases,
[O,I] fully-connected weights + [O] biases, weights-then-biases per layer)
and activation constants mirror the rust `nn` module exactly, so the same
flat parameter vector drives both engines and the runtime cross-validation
test can compare them bit-for-bit-close.

Build-time only: this module is lowered to HLO text by `compile.aot` and is
never imported at runtime.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import conv2d, fc, maxpool
from .kernels import ref

# (kind, *args): ("conv", maps, kernel) | ("pool", kernel) | ("fc", n) |
# ("out", classes). Mirrors rust config::arch (including the documented
# Table-2 large-net pool-3 reading: 6x6 pooled by 2 -> 3x3).
ARCHS = {
    "tiny": {
        "input_side": 13,
        "layers": [("conv", 3, 4), ("pool", 2), ("conv", 4, 2), ("pool", 2), ("fc", 8), ("out", 10)],
    },
    "small": {
        "input_side": 29,
        "layers": [("conv", 5, 4), ("pool", 2), ("conv", 10, 5), ("pool", 3), ("fc", 50), ("out", 10)],
    },
    "medium": {
        "input_side": 29,
        "layers": [("conv", 20, 4), ("pool", 2), ("conv", 40, 5), ("pool", 3), ("fc", 150), ("out", 10)],
    },
    "large": {
        "input_side": 29,
        "layers": [
            ("conv", 20, 4),
            ("pool", 1),
            ("conv", 60, 5),
            ("pool", 2),
            ("conv", 100, 6),
            ("pool", 2),
            ("fc", 150),
            ("out", 10),
        ],
    },
}


def param_shapes(arch: str):
    """Ordered parameter list [(name, shape), ...] for an architecture.

    The order (layer by layer, weights before biases) matches the rust flat
    parameter layout, so concatenating the raveled arrays reproduces the
    rust parameter vector exactly.
    """
    spec = ARCHS[arch]
    side = spec["input_side"]
    maps = 1
    shapes = []
    li = 0
    for layer in spec["layers"]:
        kind = layer[0]
        li += 1
        if kind == "conv":
            _, m, k = layer
            shapes.append((f"l{li}_conv_w", (m, maps, k, k)))
            shapes.append((f"l{li}_conv_b", (m,)))
            maps, side = m, side - k + 1
        elif kind == "pool":
            _, k = layer
            side //= k
        elif kind in ("fc", "out"):
            _, n = layer
            inputs = maps * side * side
            shapes.append((f"l{li}_{kind}_w", (n, inputs)))
            shapes.append((f"l{li}_{kind}_b", (n,)))
            maps, side = n, 1
        else:
            raise ValueError(f"unknown layer kind {kind!r}")
    return shapes


def param_count(arch: str) -> int:
    import math

    return sum(math.prod(s) for _, s in param_shapes(arch))


def unflatten_params(arch: str, flat):
    """Split a flat f32 vector into the ordered parameter arrays."""
    shapes = param_shapes(arch)
    expected = param_count(arch)
    assert len(flat) == expected, f"flat params {len(flat)} != expected {expected}"
    out, off = [], 0
    for _, shape in shapes:
        import math

        n = math.prod(shape)
        out.append(jnp.asarray(flat[off : off + n]).reshape(shape))
        off += n
    assert off == len(flat), f"flat params {len(flat)} != expected {off}"
    return out


def init_params(arch: str, key):
    """Glorot-uniform init (structure check / python-side tests; rust owns
    the canonical init for parity experiments)."""
    params = []
    for name, shape in param_shapes(arch):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            if len(shape) == 4:
                fan_in = shape[1] * shape[2] * shape[3]
                fan_out = shape[0] * shape[2] * shape[3]
            else:
                fan_out, fan_in = shape
            r = (6.0 / (fan_in + fan_out)) ** 0.5
            params.append(jax.random.uniform(sub, shape, jnp.float32, -r, r))
    return params


def forward(arch: str, params, image, *, use_ref: bool = False):
    """Forward-propagate one image [side, side] -> softmax probs [classes].

    `use_ref=True` routes through the pure-jnp oracle ops instead of the
    Pallas kernels (test path).
    """
    conv_f = ref.conv2d_ref if use_ref else conv2d
    pool_f = ref.maxpool_ref if use_ref else maxpool
    fc_f = ref.fc_ref if use_ref else fc

    spec = ARCHS[arch]
    x = image[None, :, :]  # [1, H, W]
    it = iter(params)
    logits = None
    for layer in spec["layers"]:
        kind = layer[0]
        if kind == "conv":
            w, b = next(it), next(it)
            x = ref.scaled_tanh(conv_f(x, w, b))
        elif kind == "pool":
            x = pool_f(x, layer[1])
        elif kind == "fc":
            w, b = next(it), next(it)
            x = ref.scaled_tanh(fc_f(x.reshape(-1), w, b))
        elif kind == "out":
            w, b = next(it), next(it)
            logits = fc_f(x.reshape(-1), w, b)
    z = logits - jnp.max(logits)
    e = jnp.exp(z)
    return e / jnp.sum(e)


def loss_fn(arch: str, params, image, label, *, use_ref: bool = False):
    """Cross-entropy loss + probs for one labelled image."""
    probs = forward(arch, params, image, use_ref=use_ref)
    onehot = jax.nn.one_hot(label, probs.shape[0], dtype=jnp.float32)
    loss = -jnp.log(jnp.clip(jnp.sum(probs * onehot), 1e-12, 1.0))
    return loss, probs


def train_step(arch: str, params, image, label, *, use_ref: bool = False):
    """One sample's (loss, probs, grads) — the unit the CHAOS workers
    publish. Grads come back in parameter order."""
    grad_fn = jax.value_and_grad(
        lambda p: loss_fn(arch, p, image, label, use_ref=use_ref), has_aux=True
    )
    (loss, probs), grads = grad_fn(params)
    return loss, probs, grads


def forward_batch(arch: str, params, images, *, use_ref: bool = False):
    """Batched forward via vmap: images [B, side, side] -> probs [B, C]."""
    return jax.vmap(lambda im: forward(arch, params, im, use_ref=use_ref))(images)
