"""AOT pipeline: lower the L2 model to HLO *text* artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime loads the text
through `HloModuleProto::from_text_file` and executes it on the PJRT CPU
client. Text — not `.serialize()` — because jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts per architecture:
  {arch}_forward.hlo.txt          (params…, image)         -> (probs,)
  {arch}_forward_b{B}.hlo.txt     (params…, images[B])     -> (probs[B],)
  {arch}_train.hlo.txt            (params…, image, label)  -> (loss, probs, grads…)
plus manifest.json describing parameter order/shapes and artifact I/O so the
rust side never guesses.
"""

import argparse
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(arch: str):
    side = model.ARCHS[arch]["input_side"]
    shapes = model.param_shapes(arch)
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    image_spec = jax.ShapeDtypeStruct((side, side), jnp.float32)

    def fn(*args):
        params, image = list(args[:-1]), args[-1]
        return (model.forward(arch, params, image),)

    return jax.jit(fn).lower(*param_specs, image_spec)


def lower_forward_batch(arch: str, batch: int):
    side = model.ARCHS[arch]["input_side"]
    shapes = model.param_shapes(arch)
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    images_spec = jax.ShapeDtypeStruct((batch, side, side), jnp.float32)

    def fn(*args):
        params, images = list(args[:-1]), args[-1]
        return (model.forward_batch(arch, params, images),)

    return jax.jit(fn).lower(*param_specs, images_spec)


def lower_train(arch: str):
    side = model.ARCHS[arch]["input_side"]
    shapes = model.param_shapes(arch)
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    image_spec = jax.ShapeDtypeStruct((side, side), jnp.float32)
    label_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(*args):
        params, image, label = list(args[:-2]), args[-2], args[-1]
        loss, probs, grads = model.train_step(arch, params, image, label)
        return (loss, probs, *grads)

    return jax.jit(fn).lower(*param_specs, image_spec, label_spec)


def build(arch: str, out_dir: str, batch: int) -> dict:
    """Lower all artifacts for one architecture; returns its manifest entry."""
    side = model.ARCHS[arch]["input_side"]
    shapes = model.param_shapes(arch)
    entries = {}

    jobs = {
        "forward": (lower_forward(arch), [f"{side}x{side} image"], ["probs"]),
        f"forward_b{batch}": (
            lower_forward_batch(arch, batch),
            [f"{batch}x{side}x{side} images"],
            ["probs_batch"],
        ),
        "train": (
            lower_train(arch),
            [f"{side}x{side} image", "label i32"],
            ["loss", "probs"] + [f"grad_{n}" for n, _ in shapes],
        ),
    }
    for name, (lowered, extra_inputs, outputs) in jobs.items():
        fname = f"{arch}_{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[name] = {
            "file": fname,
            "inputs": [n for n, _ in shapes] + extra_inputs,
            "outputs": outputs,
        }
        print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)

    return {
        "input_side": side,
        "batch": batch,
        "param_count": model.param_count(arch),
        "params": [
            {"name": n, "shape": list(s), "count": math.prod(s)} for n, s in shapes
        ],
        "artifacts": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--archs",
        default="tiny,small",
        help="comma list; medium/large cost minutes of lowering each "
        "(default tiny,small keeps `make artifacts` quick)",
    )
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "batch": args.batch, "archs": {}}
    # Merge with an existing manifest so archs can be built incrementally.
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            manifest["archs"].update(old.get("archs", {}))
        except (json.JSONDecodeError, OSError):
            pass

    for arch in args.archs.split(","):
        arch = arch.strip()
        if arch not in model.ARCHS:
            raise SystemExit(f"unknown arch '{arch}' (have {sorted(model.ARCHS)})")
        print(f"lowering {arch} …", file=sys.stderr)
        manifest["archs"][arch] = build(arch, args.out_dir, args.batch)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
