"""L1 correctness: every Pallas kernel against its pure-jnp oracle, swept
with hypothesis over shapes/values from (and beyond) the Table-2 family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, fc, maxpool
from compile.kernels.ref import conv2d_ref, fc_ref, maxpool_ref, scaled_tanh

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


# The exact (C, H, M, k) conv configurations of the paper's three networks.
TABLE2_CONVS = [
    (1, 29, 5, 4),
    (5, 13, 10, 5),  # small
    (1, 29, 20, 4),
    (20, 13, 40, 5),  # medium
    (20, 26, 60, 5),
    (60, 11, 100, 6),  # large
]


@pytest.mark.parametrize("c,h,m,k", TABLE2_CONVS)
def test_conv2d_matches_ref_on_paper_shapes(c, h, m, k):
    key = jax.random.PRNGKey(c * 1000 + h)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rand(k1, (c, h, h)), rand(k2, (m, c, k, k)), rand(k3, (m,))
    # Accumulation order differs (im2col matmul vs direct conv); on the
    # largest Table-2 reductions (C·k² up to 2160 terms) a few elements
    # land ~1e-4 apart in relative terms.
    np.testing.assert_allclose(conv2d(x, w, b), conv2d_ref(x, w, b), rtol=3e-4, atol=5e-5)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 4),
    m=st.integers(1, 5),
    k=st.integers(1, 4),
    extra=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref_hypothesis(c, m, k, extra, seed):
    h = k + extra
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rand(k1, (c, h, h)), rand(k2, (m, c, k, k)), rand(k3, (m,))
    np.testing.assert_allclose(conv2d(x, w, b), conv2d_ref(x, w, b), rtol=1e-5, atol=1e-5)


def test_conv2d_grads_match_ref_autodiff():
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x, w, b = rand(k1, (3, 9, 9)), rand(k2, (4, 3, 3, 3)), rand(k3, (4,))
    cot = rand(k4, (4, 7, 7))

    def loss_pallas(x, w, b):
        return jnp.sum(conv2d(x, w, b) * cot)

    def loss_ref(x, w, b):
        return jnp.sum(conv2d_ref(x, w, b) * cot)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r, name in zip(gp, gr, ["dx", "dw", "db"]):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-5, err_msg=name)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 4),
    k=st.integers(1, 4),
    oh=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(c, k, oh, seed):
    h = k * oh
    x = rand(jax.random.PRNGKey(seed), (c, h, h))
    np.testing.assert_allclose(maxpool(x, k), maxpool_ref(x, k), rtol=1e-6, atol=1e-6)


def test_maxpool_identity_when_k1():
    x = rand(jax.random.PRNGKey(0), (2, 5, 5))
    np.testing.assert_allclose(maxpool(x, 1), x)


def test_maxpool_grad_routes_to_argmax():
    # Distinct values: gradient must land exactly on window maxima.
    x = jnp.arange(16.0, dtype=jnp.float32).reshape(1, 4, 4)
    g = jnp.ones((1, 2, 2), jnp.float32)
    dx = jax.grad(lambda x: jnp.sum(maxpool(x, 2) * g))(x)
    expected = np.zeros((1, 4, 4), np.float32)
    for wy in range(2):
        for wx in range(2):
            expected[0, 2 * wy + 1, 2 * wx + 1] = 1.0  # max is bottom-right
    np.testing.assert_allclose(dx, expected)


def test_maxpool_grad_ties_route_once():
    # All-equal window: exactly one input receives the delta (first argmax),
    # matching the rust switches semantics.
    x = jnp.zeros((1, 2, 2), jnp.float32)
    dx = jax.grad(lambda x: jnp.sum(maxpool(x, 2)))(x)
    assert float(jnp.sum(dx)) == pytest.approx(1.0)
    assert int(jnp.count_nonzero(dx)) == 1
    assert float(dx[0, 0, 0]) == pytest.approx(1.0), "first index wins ties"


@settings(max_examples=25, deadline=None)
@given(i=st.integers(1, 40), o=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_fc_matches_ref(i, o, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rand(k1, (i,)), rand(k2, (o, i)), rand(k3, (o,))
    np.testing.assert_allclose(fc(x, w, b), fc_ref(x, w, b), rtol=1e-5, atol=1e-6)


def test_fc_grads_match_ref_autodiff():
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x, w, b = rand(k1, (12,)), rand(k2, (5, 12)), rand(k3, (5,))
    cot = rand(k4, (5,))
    gp = jax.grad(lambda x, w, b: jnp.sum(fc(x, w, b) * cot), argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lambda x, w, b: jnp.sum(fc_ref(x, w, b) * cot), argnums=(0, 1, 2))(x, w, b)
    for a, r, name in zip(gp, gr, ["dx", "dw", "db"]):
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-6, err_msg=name)


def test_scaled_tanh_constants_match_rust():
    # Same constants as rust nn::activation (A=1.7159, B=2/3).
    assert float(scaled_tanh(jnp.float32(0.0))) == 0.0
    y1 = float(scaled_tanh(jnp.float32(1.0)))
    assert y1 == pytest.approx(1.7159 * np.tanh(2.0 / 3.0), rel=1e-6)


def test_kernels_jit_compile():
    # The kernels must lower inside jit (the AOT path requirement).
    x = rand(jax.random.PRNGKey(1), (2, 8, 8))
    w = rand(jax.random.PRNGKey(2), (3, 2, 3, 3))
    b = rand(jax.random.PRNGKey(3), (3,))

    @jax.jit
    def f(x, w, b):
        return maxpool(conv2d(x, w, b), 2)

    out = f(x, w, b)
    assert out.shape == (3, 3, 3)
