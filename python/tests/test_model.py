"""L2 correctness: architecture shapes against paper Table 2, Pallas-built
model against the ref-op model, gradients, batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def params_and_image(arch, seed=0):
    key = jax.random.PRNGKey(seed)
    p = model.init_params(arch, key)
    side = model.ARCHS[arch]["input_side"]
    img = jax.random.uniform(jax.random.PRNGKey(seed + 1), (side, side), jnp.float32, -1, 1)
    return p, img


# Paper Table 2 weight counts per parameterized layer (with the documented
# large-net pool-3 reading). These must match rust nn::dims exactly.
TABLE2_COUNTS = {
    "small": [80, 5, 1250, 10, 4500, 50, 500, 10],
    "medium": [320, 20, 20000, 40, 54000, 150, 1500, 10],
    "large": [320, 20, 30000, 60, 216000, 100, 135000, 150, 1500, 10],
}


@pytest.mark.parametrize("arch", ["small", "medium", "large"])
def test_param_shapes_match_table2(arch):
    import math

    counts = [math.prod(s) for _, s in model.param_shapes(arch)]
    assert counts == TABLE2_COUNTS[arch]
    # Layer totals (weights + biases) as printed in Table 2.
    paired = [counts[i] + counts[i + 1] for i in range(0, len(counts), 2)]
    expected = {
        "small": [85, 1260, 4550, 510],
        "medium": [340, 20040, 54150, 1510],
        "large": [340, 30060, 216100, 135150, 1510],
    }[arch]
    assert paired == expected


@pytest.mark.parametrize("arch", ["tiny", "small"])
def test_forward_is_distribution(arch):
    p, img = params_and_image(arch)
    probs = model.forward(arch, p, img)
    assert probs.shape == (10,)
    assert float(jnp.sum(probs)) == pytest.approx(1.0, abs=1e-5)
    assert bool(jnp.all(probs >= 0))


@pytest.mark.parametrize("arch", ["tiny", "small"])
def test_pallas_model_matches_ref_model(arch):
    p, img = params_and_image(arch, seed=3)
    probs = model.forward(arch, p, img)
    probs_ref = model.forward(arch, p, img, use_ref=True)
    np.testing.assert_allclose(probs, probs_ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("arch", ["tiny", "small"])
def test_train_step_grads_match_ref_autodiff(arch):
    p, img = params_and_image(arch, seed=5)
    label = jnp.int32(4)
    loss, probs, grads = model.train_step(arch, p, img, label)
    loss_r, probs_r, grads_r = model.train_step(arch, p, img, label, use_ref=True)
    assert float(loss) == pytest.approx(float(loss_r), rel=1e-5)
    assert len(grads) == len(p)
    for (name, _), g, gr in zip(model.param_shapes(arch), grads, grads_r):
        np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-6, err_msg=name)


def test_train_step_reduces_loss():
    arch = "tiny"
    p, img = params_and_image(arch, seed=9)
    label = jnp.int32(2)
    loss0, _, grads = model.train_step(arch, p, img, label)
    p2 = [w - 0.1 * g for w, g in zip(p, grads)]
    loss1, _, _ = model.train_step(arch, p2, img, label)
    assert float(loss1) < float(loss0)


def test_forward_batch_matches_singles():
    arch = "tiny"
    p, _ = params_and_image(arch)
    side = model.ARCHS[arch]["input_side"]
    imgs = jax.random.uniform(jax.random.PRNGKey(11), (3, side, side), jnp.float32, -1, 1)
    batch = model.forward_batch(arch, p, imgs)
    assert batch.shape == (3, 10)
    for i in range(3):
        single = model.forward(arch, p, imgs[i])
        np.testing.assert_allclose(batch[i], single, rtol=1e-5, atol=1e-6)


def test_unflatten_roundtrip():
    arch = "small"
    p, _ = params_and_image(arch, seed=2)
    flat = np.concatenate([np.asarray(a).ravel() for a in p])
    assert flat.size == model.param_count(arch)
    back = model.unflatten_params(arch, flat)
    for a, b in zip(p, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unflatten_rejects_wrong_size():
    with pytest.raises(AssertionError):
        model.unflatten_params("tiny", np.zeros(7, np.float32))
