"""L1 analytic estimators (VMEM footprint / MAC counts) used by the
EXPERIMENTS.md §Perf TPU-efficiency estimate."""

from compile.kernels import conv2d_macs, conv2d_vmem_bytes
from compile import model

VMEM_BUDGET = 16 * 1024 * 1024  # 16 MiB


def conv_layers(arch):
    """(C, side, M, k) tuples for every conv layer of an architecture."""
    side = model.ARCHS[arch]["input_side"]
    maps = 1
    out = []
    for layer in model.ARCHS[arch]["layers"]:
        if layer[0] == "conv":
            _, m, k = layer
            out.append((maps, side, m, k))
            maps, side = m, side - k + 1
        elif layer[0] == "pool":
            side //= layer[1]
    return out


def test_all_paper_conv_layers_fit_vmem():
    for arch in ["small", "medium", "large"]:
        for (c, h, m, k) in conv_layers(arch):
            b = conv2d_vmem_bytes(c, h, m, k)
            assert b < VMEM_BUDGET, f"{arch} conv {c}x{h}-> {m} (k{k}): {b} bytes"


def test_macs_match_closed_form():
    # medium conv2: 40 maps, 20 inputs, k5, 13x13 -> 9x9
    macs = conv2d_macs(20, 13, 40, 5)
    assert macs == 40 * 20 * 25 * 81


def test_macs_scale_with_arch():
    totals = {
        arch: sum(conv2d_macs(*t) for t in conv_layers(arch))
        for arch in ["small", "medium", "large"]
    }
    assert totals["small"] < totals["medium"] < totals["large"]
    # Table 3's FProp ratio between large and small is ~92x; MACs should be
    # in the same order of magnitude of ratio.
    ratio = totals["large"] / totals["small"]
    assert 20 < ratio < 500, ratio


def test_vmem_grows_with_maps():
    assert conv2d_vmem_bytes(20, 13, 80, 5) > conv2d_vmem_bytes(20, 13, 40, 5)
