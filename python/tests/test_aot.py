"""AOT pipeline: artifacts lower to valid HLO text, the manifest describes
them faithfully, and the lowered module reproduces the python numerics when
recompiled — the same loop the rust runtime performs via PJRT."""

import json
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built():
    """Build tiny artifacts into a temp dir once for this module."""
    d = tempfile.mkdtemp(prefix="aot_test_")
    entry = aot.build("tiny", d, batch=2)
    return d, entry


def test_artifacts_written(built):
    d, entry = built
    for art in entry["artifacts"].values():
        path = os.path.join(d, art["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), f"{art['file']} is not HLO text"


def test_manifest_structure(built):
    _, entry = built
    assert entry["input_side"] == 13
    assert entry["param_count"] == model.param_count("tiny")
    names = [p["name"] for p in entry["params"]]
    assert names == [n for n, _ in model.param_shapes("tiny")]
    for p, (_, shape) in zip(entry["params"], model.param_shapes("tiny")):
        assert tuple(p["shape"]) == shape
        assert p["count"] == math.prod(shape)
    tr = entry["artifacts"]["train"]
    assert tr["outputs"][0] == "loss"
    assert tr["outputs"][1] == "probs"
    assert len(tr["outputs"]) == 2 + len(names)


def test_hlo_text_parses_back(built):
    """The emitted text must parse back into an HloModule whose program
    shape matches the manifest (parameter count and probs output). Full
    compile-and-execute round-trip coverage lives on the rust side
    (`rust/tests/runtime_roundtrip.rs`), which exercises the exact PJRT
    loader the production path uses."""
    d, entry = built
    path = os.path.join(d, entry["artifacts"]["forward"]["file"])
    module = xc._xla.hlo_module_from_text(open(path).read())
    # Parsing assigns fresh 32-bit-safe instruction ids; serialization must
    # succeed (this is what HloModuleProto::from_text_file consumes).
    proto = module.as_serialized_hlo_module_proto()
    assert len(proto) > 100
    # Entry signature check on the round-tripped text: one f32 parameter per
    # model parameter plus the image, tuple result carrying probs[10].
    text = module.to_string()
    n_params = len(model.param_shapes("tiny"))
    entry_lines = [l for l in text.splitlines() if "ENTRY" in l]
    assert entry_lines, "no ENTRY computation in round-tripped module"
    entry = entry_lines[0]
    # "ENTRY %main (Arg_0: f32[...], …) -> (f32[10])" — one Arg per model
    # parameter plus the image input.
    assert entry.count("Arg_") == n_params + 1, entry
    assert "-> (f32[10])" in entry, entry


def test_main_merges_manifest(tmp_path, monkeypatch):
    out = tmp_path / "arts"
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(out), "--archs", "tiny", "--batch", "2"],
    )
    aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    assert "tiny" in manifest["archs"]
    # Second run with the same arch must keep the manifest valid.
    aot.main()
    manifest2 = json.loads((out / "manifest.json").read_text())
    assert manifest2["archs"].keys() == manifest["archs"].keys()


def test_unknown_arch_rejected(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path), "--archs", "gigantic"]
    )
    with pytest.raises(SystemExit):
        aot.main()
